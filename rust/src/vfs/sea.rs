//! [`SeaFs`] — the paper's library, real-bytes flavour, over a stack of
//! pluggable [`Vfs`] backends.
//!
//! A Sea mount wraps a *long-term* backend (the "PFS": any [`Vfs`] —
//! a plain directory, a [`crate::vfs::StripedFs`] standing in for an
//! OST-striped Lustre, optionally rate-limited to emulate load) plus an
//! ordered set of fast **device backends** ([`DeviceSpec`]: tmpfs
//! `/dev/shm`, local disk dirs — each itself a [`Vfs`]). Every path
//! under the logical mountpoint is translated to the fastest eligible
//! device (the same `hierarchy` selection the simulator uses); paths
//! outside the mountpoint pass through to the PFS untouched — exactly
//! the interception semantics of the paper's glibc wrappers. Because
//! every placement target is a `Vfs`, decorators compose anywhere in
//! the stack (a throttled striped PFS is
//! `RateLimitedFs<StripedFs>`).
//!
//! Every decision flows through one [`PlacementEngine`]
//! (`SeaTuning::engine`): the device pick at [`Vfs::open`], the Table 1
//! management at last close, who spills when a device fills, and what
//! gets promoted back when space frees. The shipped `paper` engine
//! reproduces the paper's policy verbatim; the `temperature` engine
//! tracks per-file heat, spills the *coldest resident* file instead of
//! the active writer, and promotes hot spilled files back.
//!
//! Placement happens at [`Vfs::open`]: a writer handle reserves a device
//! slot and debits the [`crate::hierarchy::SpaceAccountant`]'s
//! per-device ledger as the file grows. When a streaming writer
//! outgrows its device, the engine's `on_pressure` hook decides: either
//! a cold victim is persisted-and-dropped so the writer stays, or the
//! handle **spills mid-stream** — under the per-file flush lock the
//! partial file migrates to the PFS backend (epoch/generation-checked,
//! writer counts preserved, sibling writes detected via per-entry
//! write serials and re-copied before the flip), the device ledger is
//! credited, and the write continues on the PFS instead of failing
//! with `NoSpace`. Only when the **last** writer handle closes is the
//! file handed to memory management. The engine's close decisions
//! (flush / evict, Table 1) are applied asynchronously by a **flush
//! pool** of worker threads (a multi-worker generalisation of the
//! paper's §5.1 daemon) so several files flush to the PFS in parallel;
//! the same pool executes promotions. Every bulk transfer — flush,
//! self-spill, victim spill, promotion, prefetch — streams through the
//! [`crate::vfs::DataMover`] in `SeaTuning::chunk_bytes` chunks with a
//! `copy_window`-bounded read-ahead, so peak copy memory is
//! O(chunk × window) instead of O(file), reads overlap writes, and a
//! chunk-striped PFS sees one large file fan out across its members.
//! When the PFS
//! advertises shard topology ([`Vfs::shard_count`], e.g. a striped
//! backend), the pool is **OST-aware**: at most
//! [`SeaTuning::per_member_concurrency`] flushes are in flight per
//! member. File metadata lives in an N-way **sharded registry** (one
//! mutex per shard) so concurrent open/read/close traffic on different
//! files never serialises on a single global lock. Worker and shard
//! counts are [`SeaTuning`] knobs (`SeaFsConfig::tuning`).
//!
//! Flush jobs carry the registry entry's *generation*: a racing
//! overwrite bumps the generation, so a stale job is discarded instead of
//! flushing half-overwritten bytes, and per-file flush serialisation
//! keeps two generations of the same file from interleaving on the PFS.
//!
//! [`OpenMode::Append`] handles resolve every write's offset from the
//! registry entry under its shard lock, so concurrent appenders reserve
//! disjoint ranges and their positioned writes can never interleave
//! within a record.
//!
//! With [`SeaTuning::compress`] on, flush and spill transfers encode
//! through the [`crate::vfs::compress`] codec stage inside the
//! DataMover's read-ahead thread, so cold PFS replicas are framed
//! containers that store fewer physical bytes (incompressible chunks
//! pass through raw). The split is strictly logical-over-physical:
//! `len()`/`size()`/`read()` and every reader handle see the bytes the
//! application wrote (compressed replicas open through a seekable
//! [`CompressedReader`]), the registry keeps logical sizes plus the
//! replica's physical footprint (`Entry::pfs_physical`), the ledger
//! and [`MgmtCounters`] carry both columns, and promotion debits
//! logical bytes because fast tiers always hold decoded copies.
//! In-place PFS writers (`ReadWrite`/`Append` on spilled or untracked
//! files) first rewrite the replica raw — a framed container never
//! takes a positioned write.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::hierarchy::{DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::obs::{trace, IoOp, Metric, Timer};
use crate::placement::engine::{
    build_engine, flush_evict_flags, Access, CloseCtx, Decision, EngineCtx, EngineKind, PlaceCtx,
    Placement, PlacementEngine, PressureCtx, Resident, TempTuning,
};
use crate::placement::rules::RuleSet;
use crate::vfs::compress::{self, CompressedReader};
use crate::vfs::mover::{
    copy_range, CodecMode, DataMover, MovePath, MoverCfg, MoverMetrics, DEFAULT_CHUNK_BYTES,
    DEFAULT_COPY_WINDOW,
};
use crate::vfs::pages::{PageCache, DEFAULT_PAGE_BUDGET, DEFAULT_PAGE_BYTES};
use crate::vfs::{OpenMode, RealFs, Vfs, VfsFile};

/// Default registry shard count: enough to keep 2× typical worker
/// counts from colliding, small enough that readdir's full sweep stays
/// cheap.
const DEFAULT_REGISTRY_SHARDS: usize = 16;

/// Default flush pool size (the paper used a single daemon; parallel
/// flushing overlaps several PFS transfers).
const DEFAULT_FLUSH_WORKERS: usize = 4;

/// Default in-flight flush cap per striped-PFS member.
const DEFAULT_PER_MEMBER_CONCURRENCY: usize = 2;

/// One fast placement target: a [`Vfs`] backend with a tier rank and a
/// byte budget.
#[derive(Clone)]
pub struct DeviceSpec {
    /// Where the device's bytes live.
    pub backend: Arc<dyn Vfs>,
    /// Tier rank: 0 = fastest.
    pub tier: u8,
    /// Usable capacity in bytes (the ledger's budget, not probed).
    pub capacity: u64,
    /// Display name (diagnostics / `device_of`).
    pub name: String,
}

impl DeviceSpec {
    /// The common case: a local directory as a [`RealFs`] backend, named
    /// after its path.
    pub fn dir(path: impl Into<PathBuf>, tier: u8, capacity: u64) -> Result<DeviceSpec> {
        let path = path.into();
        let name = path.to_string_lossy().into_owned();
        Ok(DeviceSpec {
            backend: Arc::new(RealFs::new(path)?),
            tier,
            capacity,
            name,
        })
    }

    /// Any [`Vfs`] as a device backend.
    pub fn backed(
        backend: Arc<dyn Vfs>,
        tier: u8,
        capacity: u64,
        name: impl Into<String>,
    ) -> DeviceSpec {
        DeviceSpec { backend, tier, capacity, name: name.into() }
    }
}

/// Tuning knobs for a Sea mount (formerly compile-time constants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeaTuning {
    /// Flush pool worker threads (min 1).
    pub flush_workers: usize,
    /// Registry shard count (min 1).
    pub registry_shards: usize,
    /// Max in-flight flushes per striped-PFS member; 0 disables the
    /// gate. Ignored when the PFS reports no shard topology.
    pub per_member_concurrency: usize,
    /// Chunk size for streamed management transfers
    /// ([`crate::vfs::DataMover`]); every flush / spill / promotion /
    /// prefetch moves in chunks of this size instead of one
    /// whole-file `Vec`.
    pub chunk_bytes: usize,
    /// Max in-flight chunk buffers per transfer (2 = double buffering:
    /// read-ahead overlaps write-behind). Peak copy memory per
    /// transfer is `chunk_bytes × copy_window`.
    pub copy_window: usize,
    /// Page size of the mount's [`PageCache`] (mapped I/O via
    /// [`VfsFile::map`]; `[sea] page_bytes`, `sea run --page-bytes`).
    pub page_bytes: usize,
    /// Global byte budget of the mount's [`PageCache`]: mapped views
    /// never hold more resident page bytes (dirty pages excepted —
    /// they pin until written back). `[sea] page_budget`,
    /// `sea run --page-budget`.
    pub page_budget: u64,
    /// Which [`PlacementEngine`] the mount drives (`[sea] engine = ...`,
    /// `sea run --engine ...`).
    pub engine: EngineKind,
    /// `TemperatureEngine` heat decay per logical tick
    /// ([`TempTuning::heat_decay`]).
    pub heat_decay: f64,
    /// `TemperatureEngine` heat added per touch
    /// ([`TempTuning::freq_weight`]).
    pub heat_freq_weight: f64,
    /// Free bytes a tier must have beyond a candidate's size before
    /// the `TemperatureEngine` promotes it back
    /// ([`TempTuning::promote_headroom`]).
    pub promote_headroom_bytes: u64,
    /// Compress management transfers bound for the PFS (flushes and
    /// spills) through the [`crate::vfs::compress`] codec stage in the
    /// DataMover; reads back decompress transparently and report
    /// logical sizes (`[sea] compress`, `sea run --compress`).
    pub compress: bool,
    /// Codec search effort, 1 (fast) ..= 9 (best ratio)
    /// (`[sea] compress_level`, `sea run --compress-level`).
    pub compress_level: u8,
    /// Keep a compressed chunk only when `physical < min_ratio ×
    /// logical`; chunks that do not beat the gate are stored raw
    /// (worst case one frame header per chunk). 1.0 = keep any
    /// actual shrink (`[sea] compress_min_ratio`,
    /// `sea run --compress-min-ratio`).
    pub compress_min_ratio: f64,
}

impl Default for SeaTuning {
    fn default() -> SeaTuning {
        let temp = TempTuning::default();
        SeaTuning {
            flush_workers: DEFAULT_FLUSH_WORKERS,
            registry_shards: DEFAULT_REGISTRY_SHARDS,
            per_member_concurrency: DEFAULT_PER_MEMBER_CONCURRENCY,
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            copy_window: DEFAULT_COPY_WINDOW,
            page_bytes: DEFAULT_PAGE_BYTES,
            page_budget: DEFAULT_PAGE_BUDGET,
            engine: EngineKind::Paper,
            heat_decay: temp.heat_decay,
            heat_freq_weight: temp.freq_weight,
            promote_headroom_bytes: temp.promote_headroom,
            compress: false,
            compress_level: 3,
            compress_min_ratio: 1.0,
        }
    }
}

impl SeaTuning {
    /// The temperature-engine slice of these knobs.
    pub fn temp_tuning(&self) -> TempTuning {
        TempTuning {
            heat_decay: self.heat_decay,
            freq_weight: self.heat_freq_weight,
            promote_headroom: self.promote_headroom_bytes,
        }
    }

    /// The mover codec stage these knobs select.
    pub fn codec_mode(&self) -> CodecMode {
        if self.compress {
            CodecMode::Encode {
                level: self.compress_level.clamp(1, 9),
                min_ratio_pct: (self.compress_min_ratio.clamp(0.01, 1.0) * 100.0)
                    .round() as u16,
            }
        } else {
            CodecMode::Off
        }
    }
}

/// Configuration of a real Sea mount.
pub struct SeaFsConfig {
    /// Logical mountpoint prefix (e.g. `/sea`).
    pub mountpoint: PathBuf,
    /// Fast device backends, each with tier rank and capacity.
    pub devices: Vec<DeviceSpec>,
    /// Long-term storage backend.
    pub pfs: Arc<dyn Vfs>,
    /// Max file size `F` declared by the user.
    pub max_file_size: u64,
    /// Parallel process count `p` declared by the user.
    pub parallel_procs: u64,
    /// Rule lists.
    pub rules: RuleSet,
    /// PRNG seed for same-tier shuffling.
    pub seed: u64,
    /// Pool / registry / scheduling knobs.
    pub tuning: SeaTuning,
}

/// One device's ledger joined with its hierarchy metadata (diagnostics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceLedger {
    /// Device display name.
    pub name: String,
    /// Tier rank.
    pub tier: u8,
    /// Configured capacity.
    pub capacity: u64,
    /// Bytes currently free.
    pub free: u64,
    /// Bytes currently placed.
    pub used: u64,
    /// Cumulative bytes ever debited.
    pub debits: u64,
    /// Cumulative bytes ever credited back.
    pub credits: u64,
    /// Logical bytes the current `used` (physical) represents —
    /// larger than `used` when the device stores compressed replicas.
    pub logical: u64,
}

/// Cumulative management/placement activity of a mount (diagnostics,
/// `sea stat`, benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MgmtCounters {
    /// Files replicated to the PFS by the flush pool.
    pub flushes: u64,
    /// Local copies dropped by the flush pool (incl. victim spills).
    pub evictions: u64,
    /// Mid-stream migrations of the active writer to the PFS.
    pub self_spills: u64,
    /// Cold resident files persisted-and-dropped under pressure so an
    /// active writer could stay on its device.
    pub victim_spills: u64,
    /// PFS-resident files pulled back onto a fast tier.
    pub promotions: u64,
    /// Files pulled in by the mount-time prefetch pass.
    pub prefetched: u64,
    /// Bytes streamed to the PFS by close-time flushes (logical —
    /// what the application wrote).
    pub flush_bytes: u64,
    /// Bytes streamed by mid-stream self-spills and victim spills
    /// (logical).
    pub spill_bytes: u64,
    /// Bytes streamed back onto fast tiers by promotions (logical).
    pub promote_bytes: u64,
    /// Bytes streamed in by prefetch passes (logical).
    pub prefetch_bytes: u64,
    /// Post-codec bytes flushes actually wrote to the PFS (equals
    /// `flush_bytes` with compression off; the codec's bytes-out
    /// gauge when on).
    pub flush_physical_bytes: u64,
    /// Post-codec bytes spills actually wrote to the PFS.
    pub spill_physical_bytes: u64,
    /// Physical PFS bytes promotions read through the decoder.
    pub promote_physical_bytes: u64,
    /// Physical PFS bytes prefetches read through the decoder.
    pub prefetch_physical_bytes: u64,
    /// High-water mark of allocated copy-buffer bytes across all
    /// concurrent management transfers: the bounded-memory gauge (one
    /// transfer never allocates more than `chunk_bytes × copy_window`).
    pub peak_copy_buffer_bytes: u64,
    /// Pages faulted in by mapped views over this mount's [`PageCache`].
    pub page_faults: u64,
    /// Mapped-view page lookups served from cache.
    pub page_hits: u64,
    /// Clean pages evicted to keep the cache under its byte budget.
    pub page_evictions: u64,
    /// Dirty mapped bytes written back through handles.
    pub page_writeback_bytes: u64,
    /// Page hits served to a view other than the one that faulted the
    /// frame in — cross-view frame sharing at work.
    pub page_shared_hits: u64,
    /// Duplicate concurrent page faults collapsed onto one frame.
    pub page_frames_deduped: u64,
    /// Page bytes resident right now.
    pub page_resident_bytes: u64,
    /// High-water mark of resident page bytes: the mapped-I/O
    /// bounded-memory gauge (stays within `page_budget` unless dirty
    /// pages pin it).
    pub page_peak_resident_bytes: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    /// Device holding the local copy, or `None` once a mid-stream spill
    /// relocated the (still-open) file to the PFS.
    dev: Option<DeviceRef>,
    size: u64,
    flushed: bool,
    /// Content version: bumped on every (re)placement, writer open, or
    /// spill; flush jobs carry the generation they were enqueued for and
    /// stand down when it no longer matches (a newer write superseded
    /// them).
    generation: u64,
    /// Entry identity: assigned when the entry is inserted and never
    /// changed in place. Handles record the epoch of the entry their
    /// writer count lives in, so a handle orphaned by `drop_local`
    /// (entry replaced) never touches the superseding entry, while
    /// concurrent in-place writers (who share one entry across
    /// generation bumps) still decrement correctly on close.
    epoch: u64,
    /// Open writer handles; management is deferred until this drops to 0.
    writers: u32,
    /// Device writes reserved under the shard lock whose backend I/O has
    /// not completed yet. A spill must drain this to 0 before it flips.
    pending: u32,
    /// Per-entry write serial: bumped when a device write completes.
    /// A spill snapshots it before its bulk copy; a mismatch at flip
    /// time means sibling writes landed mid-copy and must be re-copied.
    serial: u64,
    /// Spill phase 1 armed: completing writes log their ranges into
    /// `recopy` so the spill can re-copy them before the flip.
    recopy_armed: bool,
    /// Spill phase 2: new reservations are refused ([`Step::Busy`])
    /// until the entry flips to the PFS.
    migrating: bool,
    /// `(offset, len)` of writes completed since arming.
    recopy: Vec<(u64, u64)>,
    /// Physical size of the file's *compressed* PFS replica, when one
    /// exists (`None` = no replica or a raw one). `size` stays
    /// logical; this is what the replica costs the PFS and what a
    /// promotion will actually read.
    pfs_physical: Option<u64>,
}

impl Entry {
    fn new(dev: Option<DeviceRef>, size: u64, flushed: bool, gen: u64, writers: u32) -> Entry {
        Entry {
            dev,
            size,
            flushed,
            generation: gen,
            epoch: gen,
            writers,
            pending: 0,
            serial: 0,
            recopy_armed: false,
            migrating: false,
            recopy: Vec::new(),
            pfs_physical: None,
        }
    }

    fn with_pfs_physical(mut self, physical: Option<u64>) -> Entry {
        self.pfs_physical = physical;
        self
    }
}

/// One unit of deferred background work for the flush pool.
enum Job {
    /// Table 1 management at last close: flush and/or evict `rel`.
    Mgmt {
        rel: String,
        gen: u64,
        flush: bool,
        evict: bool,
    },
    /// Pull a PFS-resident file back onto a fast tier.
    Promote { rel: String, tier: u8 },
}

impl Job {
    fn rel(&self) -> &str {
        match self {
            Job::Mgmt { rel, .. } | Job::Promote { rel, .. } => rel,
        }
    }
}

/// N-way sharded `rel -> Entry` map: per-shard mutexes instead of one
/// global lock.
struct Registry {
    shards: Vec<Mutex<HashMap<String, Entry>>>,
}

impl Registry {
    fn new(n: usize) -> Registry {
        Registry {
            shards: (0..n.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<HashMap<String, Entry>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn get(&self, key: &str) -> Option<Entry> {
        self.shard(key).lock().expect("registry poisoned").get(key).cloned()
    }

    fn contains(&self, key: &str) -> bool {
        self.shard(key).lock().expect("registry poisoned").contains_key(key)
    }

    fn insert(&self, key: String, e: Entry) {
        self.shard(&key).lock().expect("registry poisoned").insert(key, e);
    }

    fn remove(&self, key: &str) -> Option<Entry> {
        self.shard(key).lock().expect("registry poisoned").remove(key)
    }

    /// Remove `key` only when `pred` holds for its current entry.
    fn remove_if(&self, key: &str, pred: impl FnOnce(&Entry) -> bool) -> Option<Entry> {
        let mut m = self.shard(key).lock().expect("registry poisoned");
        let matches = match m.get(key) {
            Some(e) => pred(e),
            None => false,
        };
        if matches {
            m.remove(key)
        } else {
            None
        }
    }

    /// Mutate the entry for `key` under its shard lock, returning the
    /// closure's result (or `None` when absent).
    fn update<R>(&self, key: &str, f: impl FnOnce(&mut Entry) -> R) -> Option<R> {
        let mut m = self.shard(key).lock().expect("registry poisoned");
        m.get_mut(key).map(f)
    }

    /// Run `f` with `key`'s whole shard map locked — one critical
    /// section for create-or-join decisions (append opens).
    fn with_shard<R>(&self, key: &str, f: impl FnOnce(&mut HashMap<String, Entry>) -> R) -> R {
        let mut m = self.shard(key).lock().expect("registry poisoned");
        f(&mut m)
    }

    /// Snapshot of every key across all shards.
    fn keys(&self) -> Vec<String> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().expect("registry poisoned").keys().cloned());
        }
        out
    }
}

/// OST-aware flush gate: at most `per_member` in-flight flushes per
/// striped-PFS member.
struct PfsSlots {
    per_member: usize,
    members: usize,
    /// (current in-flight, observed peak) per member.
    state: Mutex<(Vec<usize>, Vec<usize>)>,
    freed: Condvar,
}

impl PfsSlots {
    fn acquire(&self, member: usize) -> SlotGuard<'_> {
        let mut st = self.state.lock().expect("pfs slots poisoned");
        while st.0[member] >= self.per_member {
            st = self.freed.wait(st).expect("pfs slots poisoned");
        }
        st.0[member] += 1;
        if st.0[member] > st.1[member] {
            st.1[member] = st.0[member];
        }
        SlotGuard { slots: self, member }
    }
}

struct SlotGuard<'a> {
    slots: &'a PfsSlots,
    member: usize,
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.slots.state.lock().expect("pfs slots poisoned");
        st.0[self.member] = st.0[self.member].saturating_sub(1);
        drop(st);
        self.slots.freed.notify_all();
    }
}

struct Shared {
    /// Devices with their backends ([`Hierarchy::add_backed`]).
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    registry: Registry,
    pfs: Arc<dyn Vfs>,
    /// The one placement brain: every device pick, mgmt decision, spill
    /// victim and promotion flows through it.
    engine: Arc<dyn PlacementEngine>,
    /// Mgmt statistics.
    counters: Mutex<MgmtCounters>,
    /// Monotonic generation source for registry entries.
    generations: AtomicU64,
    /// Flush-pool inbox; `None` once the mount is dropped.
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    /// Jobs enqueued but not yet fully processed.
    pending: Mutex<u64>,
    idle: Condvar,
    /// Per-file flush serialisation (two generations of the same file
    /// must not interleave their PFS writes).
    flush_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    /// Per-member in-flight flush gate, when the PFS is sharded.
    pfs_slots: Option<PfsSlots>,
    /// Streamed-transfer tuning (chunk size, in-flight window).
    mover_cfg: MoverCfg,
    /// Codec stage for PFS-bound transfers (`SeaTuning::compress`):
    /// [`CodecMode::Encode`] makes every flush / spill write a framed
    /// compressed replica; reads back come through a
    /// [`CompressedReader`].
    codec: CodecMode,
    /// DataMover gauges: bytes per management path, peak buffer bytes.
    mover: MoverMetrics,
    /// The mount's page cache for mapped views ([`VfsFile::map`]):
    /// budget and page size from `SeaTuning::{page_budget, page_bytes}`.
    pages: Arc<PageCache>,
}

impl Shared {
    fn backend(&self, dev: DeviceRef) -> &Arc<dyn Vfs> {
        self.hierarchy.backend(dev).expect("sea device carries a backend")
    }

    fn next_gen(&self) -> u64 {
        self.generations.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The engine's view of this mount's devices.
    fn ectx(&self) -> EngineCtx<'_> {
        EngineCtx { hierarchy: &self.hierarchy, accountant: &self.accountant }
    }

    /// Hand a job to the flush pool.
    fn enqueue(&self, job: Job) {
        let tx = self.tx.lock().expect("tx poisoned");
        if let Some(tx) = tx.as_ref() {
            *self.pending.lock().expect("pending poisoned") += 1;
            if tx.send(job).is_err() {
                *self.pending.lock().expect("pending poisoned") -= 1;
                self.idle.notify_all();
            }
        }
    }

    /// Enqueue the engine's close decisions for `rel` (no-op when the
    /// engine decided Keep).
    fn enqueue_close(&self, rel: &str, gen: u64, decisions: &[Decision]) {
        let (flush, evict) = flush_evict_flags(rel, decisions);
        if flush || evict {
            self.enqueue(Job::Mgmt { rel: rel.to_string(), gen, flush, evict });
        }
        for d in decisions {
            if let Decision::Promote { rel, tier } = d {
                self.enqueue(Job::Promote { rel: rel.clone(), tier: *tier });
            }
        }
    }

    /// Tell the engine `size` bytes came free on `dev`; execute any
    /// promotion decisions asynchronously on the flush pool.
    fn notify_freed(&self, dev: DeviceRef, size: u64) {
        for d in self.engine.on_freed(self.ectx(), dev, size) {
            if let Decision::Promote { rel, tier } = d {
                self.enqueue(Job::Promote { rel, tier });
            }
        }
    }

    /// Credit the ledger and notify the engine in one step.
    fn credit_and_notify(&self, dev: DeviceRef, size: u64) {
        self.accountant.credit(dev, size);
        self.notify_freed(dev, size);
    }

    /// Insert a freshly placed entry, reclaiming whatever entry raced
    /// in between the caller's `drop_local` and now (a concurrent
    /// promotion, or another writer's placement): the loser's ledger
    /// debit is credited back and its device file removed — unless it
    /// lives on the very path the caller just wrote.
    fn insert_placed(&self, rel: &str, entry: Entry) {
        let new_dev = entry.dev;
        let prev = self
            .registry
            .with_shard(rel, |m| m.insert(rel.to_string(), entry));
        if let Some(p) = prev {
            if let Some(d) = p.dev {
                if Some(d) != new_dev {
                    let _ = self.backend(d).unlink(Path::new(rel));
                }
                self.credit_and_notify(d, p.size);
            }
        }
    }

    /// Snapshot of closed, device-resident files: the engine's
    /// spill-victim candidates.
    fn residents(&self) -> Vec<Resident> {
        let mut out = Vec::new();
        for shard in &self.registry.shards {
            let m = shard.lock().expect("registry poisoned");
            for (rel, e) in m.iter() {
                if e.writers == 0 && !e.migrating && !e.recopy_armed {
                    if let Some(dev) = e.dev {
                        out.push(Resident {
                            rel: rel.clone(),
                            dev,
                            size: e.size,
                            // a known compressed replica makes this
                            // resident cheap to keep cold
                            physical: e.pfs_physical.unwrap_or(e.size),
                        });
                    }
                }
            }
        }
        out
    }

    /// Persist-and-drop a closed resident file *now* (an engine
    /// `SpillVictim` decision): the victim's bytes move to the PFS and
    /// its device space is credited, so the pressured writer can stay.
    /// Returns whether the victim's device copy is gone.
    fn spill_victim(&self, rel: &str) -> bool {
        let lk = self.flush_lock(rel);
        let evicted = {
            let _guard = lk.lock().expect("flush lock poisoned");
            match self.registry.get(rel) {
                Some(e) if e.writers == 0 && e.dev.is_some() => {
                    // victim traffic is spill traffic in the gauges
                    run_mgmt(self, rel, e.generation, true, true, MovePath::Spill);
                    match self.registry.get(rel) {
                        Some(e2) => e2.dev.is_none(),
                        None => true,
                    }
                }
                _ => false,
            }
        };
        drop(lk);
        self.release_flush_lock(rel);
        if evicted {
            self.counters.lock().expect("counters poisoned").victim_spills += 1;
        }
        evicted
    }

    fn flush_lock(&self, rel: &str) -> Arc<Mutex<()>> {
        let mut m = self.flush_locks.lock().expect("flush locks poisoned");
        m.entry(rel.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(())))
            .clone()
    }

    fn release_flush_lock(&self, rel: &str) {
        let mut m = self.flush_locks.lock().expect("flush locks poisoned");
        if let Some(a) = m.get(rel) {
            if Arc::strong_count(a) == 1 {
                m.remove(rel);
            }
        }
    }

    /// Acquire the PFS member slot(s) for flushing `size` bytes of
    /// `rel`, when the gate is active. A whole-file PFS charges the one
    /// member the path hashes to; a **stripe-mode** PFS fans one file's
    /// writes across members, so every member that holds a part of the
    /// file is charged (the PR 4 gap: charging a single slot let one
    /// fan-out flush exceed `per_member_concurrency` on the other
    /// members). Slots are acquired in member order so concurrent
    /// fan-out flushes cannot deadlock on partial acquisitions.
    fn pfs_slots_for(&self, rel: &str, size: u64) -> Vec<SlotGuard<'_>> {
        let Some(s) = self.pfs_slots.as_ref() else {
            return Vec::new();
        };
        match self.pfs.stripe_bytes() {
            Some(stripe) if stripe > 0 => {
                // stripes land round-robin from member 0: a file of N
                // stripes touches members 0..min(N, members)
                let nstripes = ((size + stripe - 1) / stripe).max(1);
                let touched = nstripes.min(s.members as u64) as usize;
                (0..touched).map(|m| s.acquire(m)).collect()
            }
            _ => {
                let m = self.pfs.shard_of(Path::new(rel)).unwrap_or(0) % s.members;
                vec![s.acquire(m)]
            }
        }
    }

    /// A [`DataMover`] for one transfer whose destination is `dst`:
    /// chunking is aligned to the destination's stripe unit (when it
    /// advertises one) so consecutive chunks of a large file fan out
    /// across striped members, and the mount's gauges observe the
    /// transfer.
    fn mover_to(&self, dst: &dyn Vfs, class: MovePath) -> DataMover<'_> {
        DataMover::new(self.mover_cfg.aligned_to(dst.stripe_bytes()), class)
            .with_metrics(&self.mover)
    }

    /// Stream exactly `size` logical bytes of `src` into `rel` on
    /// `dst` — the one copy-with-rollback every streamed management
    /// transfer (flush, victim spill, promotion, prefetch) shares.
    /// Returns the physical bytes written. On PFS-bound paths (Flush /
    /// Spill) the mount's codec stage engages, so the destination
    /// becomes a framed compressed replica; `src_physical` lets a
    /// decode-through source (a [`CompressedReader`]) report the true
    /// physical PFS traffic. A short copy (the source shrank
    /// mid-stream) is an error, and any failure after the destination
    /// was opened removes the partial file: a missing destination is
    /// detectable, a silently truncated (or trailer-less) one is not.
    fn stream_into(
        &self,
        dst: &Arc<dyn Vfs>,
        rel: &str,
        src: &mut dyn VfsFile,
        size: u64,
        class: MovePath,
        src_physical: Option<u64>,
    ) -> Result<u64> {
        let mut cfg = self.mover_cfg.aligned_to(dst.stripe_bytes());
        if matches!(class, MovePath::Flush | MovePath::Spill) {
            cfg.codec = self.codec;
        }
        let res = match dst.open(Path::new(rel), OpenMode::Write) {
            Ok(mut out) => {
                let mut mover = DataMover::new(cfg, class).with_metrics(&self.mover);
                if let Some(p) = src_physical {
                    mover = mover.with_physical(p);
                }
                match mover.copy_counted(src, out.as_mut(), size) {
                    Ok((n, phys)) if n == size => Ok(phys),
                    Ok(_) => Err(Error::io(
                        rel,
                        std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "source shrank mid-copy",
                        ),
                    )),
                    Err(e) => Err(e),
                }
            }
            Err(e) => return Err(e),
        };
        if res.is_err() {
            let _ = dst.unlink(Path::new(rel));
        }
        res
    }

    /// Whether PFS-bound transfers encode (the mount's codec is on).
    fn encodes_pfs(&self) -> bool {
        self.codec != CodecMode::Off
    }

    /// Open `rel`'s PFS copy for reading as a *logical* byte stream:
    /// compressed replicas come back wrapped in a [`CompressedReader`]
    /// (seekable per-frame decode), plain files come back as-is.
    fn open_pfs_reader(&self, rel: &str) -> Result<Box<dyn VfsFile>> {
        let mut f = self.pfs.open(Path::new(rel), OpenMode::Read)?;
        match compress::probe(f.as_mut())? {
            Some(meta) => Ok(Box::new(CompressedReader::new(f, meta))),
            None => Ok(f),
        }
    }

    /// Logical size of `rel`'s PFS copy: a compressed replica reports
    /// the bytes it decodes to, a plain file its on-disk length —
    /// `size()`/`len()` never leak the container's physical framing.
    fn pfs_logical_size(&self, rel: &str) -> Result<u64> {
        let mut f = self.pfs.open(Path::new(rel), OpenMode::Read)?;
        match compress::logical_len(f.as_mut())? {
            Some(n) => Ok(n),
            None => f.len(),
        }
    }

    /// [`Shared::open_pfs_reader`], also reporting the logical length
    /// and — when the replica is compressed — its physical size.
    fn open_pfs_source(&self, rel: &str) -> Result<(Box<dyn VfsFile>, u64, Option<u64>)> {
        let mut f = self.pfs.open(Path::new(rel), OpenMode::Read)?;
        let physical = f.len()?;
        match compress::probe(f.as_mut())? {
            Some(meta) => {
                let logical = meta.logical_len;
                Ok((Box::new(CompressedReader::new(f, meta)), logical, Some(physical)))
            }
            None => Ok((f, physical, None)),
        }
    }

    /// Rewrite `rel`'s PFS replica as plain bytes when (and only when)
    /// it is currently compressed — the escape hatch for in-place PFS
    /// writers (`ReadWrite` / `Append` on an untracked or spilled
    /// file), which patch arbitrary offsets and would silently corrupt
    /// a framed replica. Decodes into a temp name, then renames over.
    fn materialize_raw_on_pfs(&self, rel: &str) -> Result<()> {
        let mut f = self.pfs.open(Path::new(rel), OpenMode::Read)?;
        let Some(meta) = compress::probe(f.as_mut())? else {
            return Ok(()); // already plain
        };
        let logical = meta.logical_len;
        let mut reader = CompressedReader::new(f, meta);
        let tmp = format!("{rel}.sea_raw_tmp");
        {
            let mut out = self.pfs.open(Path::new(&tmp), OpenMode::Write)?;
            let n = copy_range(
                &mut reader,
                out.as_mut(),
                0,
                logical,
                self.mover_cfg.chunk_bytes,
                Some(&self.mover),
            )?;
            if n != logical {
                let _ = self.pfs.unlink(Path::new(&tmp));
                return Err(Error::io(
                    rel,
                    std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "compressed replica ended early during rewrite",
                    ),
                ));
            }
        }
        drop(reader);
        if let Err(e) = self.pfs.rename(Path::new(&tmp), Path::new(rel)) {
            let _ = self.pfs.unlink(Path::new(&tmp));
            return Err(e);
        }
        Ok(())
    }
}

/// The real-bytes Sea mount.
pub struct SeaFs {
    mountpoint: PathBuf,
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl SeaFs {
    /// Mount: builds the hierarchy over the device backends, constructs
    /// the [`PlacementEngine`] (`tuning.engine`), spawns the flush pool,
    /// arms the per-member gate when the PFS is sharded, and runs the
    /// mount-time prefetch pass when `.sea_prefetchlist` names inputs.
    pub fn mount(cfg: SeaFsConfig) -> Result<SeaFs> {
        if cfg.devices.is_empty() {
            return Err(Error::Config(
                "sea requires at least one fast device (plus the PFS)".into(),
            ));
        }
        let mut hierarchy = Hierarchy::new();
        for d in &cfg.devices {
            hierarchy.add_backed(d.tier, d.capacity, d.name.clone(), d.backend.clone());
        }
        let accountant = SpaceAccountant::new(&hierarchy);
        let pfs_slots = match (cfg.pfs.shard_count(), cfg.tuning.per_member_concurrency) {
            (Some(members), per_member) if members > 0 && per_member > 0 => Some(PfsSlots {
                per_member,
                members,
                state: Mutex::new((vec![0; members], vec![0; members])),
                freed: Condvar::new(),
            }),
            _ => None,
        };
        let select = SelectCfg {
            max_file_size: cfg.max_file_size,
            parallel_procs: cfg.parallel_procs,
        };
        let has_prefetch = !cfg.rules.prefetch.is_empty();
        let engine = build_engine(
            cfg.tuning.engine,
            select,
            cfg.rules,
            cfg.seed,
            cfg.tuning.temp_tuning(),
        );
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(Shared {
            hierarchy,
            accountant,
            registry: Registry::new(cfg.tuning.registry_shards),
            pfs: cfg.pfs,
            engine,
            counters: Mutex::new(MgmtCounters::default()),
            generations: AtomicU64::new(0),
            tx: Mutex::new(Some(tx)),
            pending: Mutex::new(0),
            idle: Condvar::new(),
            flush_locks: Mutex::new(HashMap::new()),
            pfs_slots,
            mover_cfg: MoverCfg {
                chunk_bytes: cfg.tuning.chunk_bytes.max(1),
                copy_window: cfg.tuning.copy_window.max(1),
                codec: CodecMode::Off,
            },
            codec: cfg.tuning.codec_mode(),
            mover: MoverMetrics::default(),
            pages: Arc::new(PageCache::new(
                cfg.tuning.page_bytes,
                cfg.tuning.page_budget,
            )),
        });
        let rx = Arc::new(Mutex::new(rx));
        let nworkers = cfg.tuning.flush_workers.max(1);
        let mut workers = Vec::with_capacity(nworkers);
        for w in 0..nworkers {
            let sh = shared.clone();
            let rx = rx.clone();
            let h = std::thread::Builder::new()
                .name(format!("sea-flush-{w}"))
                .spawn(move || flush_worker(sh, rx))
                .map_err(|e| Error::io("<thread>", e))?;
            workers.push(h);
        }
        let sea = SeaFs {
            mountpoint: cfg.mountpoint,
            shared,
            workers: Mutex::new(workers),
        };
        if has_prefetch {
            sea.prefetch_pass();
        }
        Ok(sea)
    }

    /// Mount-relative form of `path`, or `None` when outside the mount.
    pub fn rel_of(&self, path: &Path) -> Option<String> {
        path.strip_prefix(&self.mountpoint)
            .ok()
            .map(|r| r.to_string_lossy().into_owned())
    }

    /// Where a mount-relative file currently lives (diagnostics);
    /// `None` when it is not on a fast device (unknown, or spilled /
    /// flushed to the PFS).
    pub fn device_of(&self, rel: &str) -> Option<String> {
        self.shared
            .registry
            .get(rel)
            .and_then(|e| e.dev)
            .map(|d| self.shared.hierarchy.info(d).name.clone())
    }

    /// (flushes, evictions) executed by the flush pool so far.
    pub fn mgmt_counters(&self) -> (u64, u64) {
        let c = self.shared.counters.lock().expect("counters poisoned");
        (c.flushes, c.evictions)
    }

    /// Full management/placement counters (spills, promotions,
    /// prefetches, the streamed-transfer byte gauges and the
    /// page-cache gauges included).
    pub fn counters(&self) -> MgmtCounters {
        let mut c = *self.shared.counters.lock().expect("counters poisoned");
        let m = &self.shared.mover;
        c.flush_bytes = m.moved(MovePath::Flush);
        c.spill_bytes = m.moved(MovePath::Spill);
        c.promote_bytes = m.moved(MovePath::Promote);
        c.prefetch_bytes = m.moved(MovePath::Prefetch);
        c.flush_physical_bytes = m.moved_physical(MovePath::Flush);
        c.spill_physical_bytes = m.moved_physical(MovePath::Spill);
        c.promote_physical_bytes = m.moved_physical(MovePath::Promote);
        c.prefetch_physical_bytes = m.moved_physical(MovePath::Prefetch);
        c.peak_copy_buffer_bytes = m.peak_buffer_bytes();
        let p = self.shared.pages.stats();
        c.page_faults = p.faults;
        c.page_hits = p.hits;
        c.page_evictions = p.evictions;
        c.page_writeback_bytes = p.writeback_bytes;
        c.page_shared_hits = p.shared_hits;
        c.page_frames_deduped = p.frames_deduped;
        c.page_resident_bytes = p.resident_bytes;
        c.page_peak_resident_bytes = p.peak_resident_bytes;
        c
    }

    /// The mount's [`PageCache`] (mapped views opened through this
    /// mount should use it so `sea stat` sees their gauges).
    pub fn page_cache(&self) -> Arc<PageCache> {
        self.shared.pages.clone()
    }

    /// Display name of the mount's placement engine.
    pub fn engine_name(&self) -> &'static str {
        self.shared.engine.name()
    }

    /// The mount's streamed-transfer chunk size
    /// (`SeaTuning::chunk_bytes`, min-clamped at mount). The daemon
    /// forwards it to clients in the `Hello` reply as their default
    /// readahead window.
    pub fn chunk_bytes(&self) -> usize {
        self.shared.mover_cfg.chunk_bytes
    }

    /// Per-device ledger lines joined with device metadata.
    pub fn ledger(&self) -> Vec<DeviceLedger> {
        let lines = self.shared.accountant.lines();
        self.shared
            .hierarchy
            .iter()
            .zip(lines)
            .map(|((_, info), l)| DeviceLedger {
                name: info.name.clone(),
                tier: info.tier,
                capacity: info.capacity,
                free: l.free,
                used: l.used,
                debits: l.debits,
                credits: l.credits,
                logical: l.logical,
            })
            .collect()
    }

    /// Peak in-flight flushes observed per PFS member, when the
    /// OST-aware gate is active (diagnostics / benchmarks).
    pub fn flush_member_peaks(&self) -> Option<Vec<usize>> {
        self.shared
            .pfs_slots
            .as_ref()
            .map(|s| s.state.lock().expect("pfs slots poisoned").1.clone())
    }

    /// Prefetch: recursively copy every PFS file under `dir`
    /// (mount-relative) the engine wants prefetched
    /// (`.sea_prefetchlist`) into fast devices. I/O errors on matched
    /// files propagate — a caller can tell "nothing matched" from
    /// "the PFS is failing".
    pub fn prefetch_dir(&self, dir: &str) -> Result<usize> {
        self.prefetch_walk(dir, true)
    }

    /// Mount-time prefetch pass: walk the whole PFS tree. Best-effort
    /// (`strict = false`): unreadable entries are skipped, a mount
    /// never fails on prefetch.
    fn prefetch_pass(&self) -> usize {
        let n = self.prefetch_walk("", false).unwrap_or(0);
        if n > 0 {
            self.shared.counters.lock().expect("counters poisoned").prefetched += n as u64;
        }
        n
    }

    /// Shared prefetch walker: pull every engine-matched file under
    /// `root` into the fastest eligible tier (ledger-debited, marked
    /// flushed — the bytes came *from* the PFS, so eviction is always
    /// safe). `strict` propagates I/O errors (the explicit
    /// [`SeaFs::prefetch_dir`] API); lenient mode skips them (the
    /// mount-time pass).
    fn prefetch_walk(&self, root: &str, strict: bool) -> Result<usize> {
        let sh = &self.shared;
        let mut n = 0usize;
        let mut stack = vec![root.to_string()];
        while let Some(dir) = stack.pop() {
            let names = match sh.pfs.readdir(Path::new(&dir)) {
                Ok(names) => names,
                // the root must be listable in strict mode; deeper
                // failures (entry vanished mid-scan) are skipped
                Err(e) if strict && dir == root => return Err(e),
                Err(_) => continue,
            };
            for name in names {
                let rel = if dir.is_empty() { name } else { format!("{dir}/{name}") };
                // directories list their children; files refuse readdir
                if sh.pfs.readdir(Path::new(&rel)).is_ok() {
                    stack.push(rel);
                    continue;
                }
                if !sh.engine.wants_prefetch(&rel) || sh.registry.contains(&rel) {
                    continue;
                }
                match self.place_streamed(&rel) {
                    Ok(true) => n += 1,
                    Ok(false) => {}
                    Err(Error::NotFound(_)) => {} // vanished mid-scan
                    Err(e) if strict => return Err(e),
                    Err(_) => {}
                }
            }
        }
        Ok(n)
    }

    /// Core whole-file placement: write `data` to the device the engine
    /// picks. Returns the chosen device and registry generation, or
    /// `None` when it fell through to the PFS.
    fn place_and_write(&self, rel: &str, data: &[u8]) -> Result<Option<(DeviceRef, u64)>> {
        let sh = &self.shared;
        // overwrite: free the previous local copy first
        self.drop_local(rel)?;
        let pick = sh.engine.place(
            sh.ectx(),
            PlaceCtx { rel, size: data.len() as u64, prefetch: false },
        );
        match pick {
            Placement::Device(dev) => {
                trace::instant("place", "placement", "device", data.len() as u64);
                if let Err(e) = sh.backend(dev).write(Path::new(rel), data) {
                    // placement reserved the bytes; a failed backend
                    // write must give them back
                    sh.accountant.credit(dev, data.len() as u64);
                    return Err(e);
                }
                let gen = sh.next_gen();
                sh.insert_placed(rel, Entry::new(Some(dev), data.len() as u64, false, gen, 0));
                Ok(Some((dev, gen)))
            }
            Placement::Pfs => {
                trace::instant("place", "placement", "pfs", data.len() as u64);
                sh.pfs.write(Path::new(rel), data)?;
                Ok(None)
            }
        }
    }

    /// Streamed prefetch placement: pull the PFS copy of `rel` into
    /// the device the engine picks, in bounded chunks — no whole-file
    /// `Vec`, regardless of input size. Returns whether a device
    /// placement happened (`false`: the engine sent it to the PFS,
    /// where the bytes already live). The entry is inserted `flushed`:
    /// the bytes came *from* the PFS, so a later evict is always safe.
    fn place_streamed(&self, rel: &str) -> Result<bool> {
        let sh = &self.shared;
        // decode-through source: `size` is logical, what the device
        // placement costs; `phys` what the PFS replica stores
        let (mut src, size, phys) = sh.open_pfs_source(rel)?;
        // overwrite: free any previous local copy first
        self.drop_local(rel)?;
        let pick = sh
            .engine
            .place(sh.ectx(), PlaceCtx { rel, size, prefetch: true });
        let Placement::Device(dev) = pick else {
            trace::instant("place", "placement", "pfs", size);
            return Ok(false);
        };
        trace::instant("place", "placement", "prefetch", size);
        let backend = sh.backend(dev).clone();
        if let Err(e) =
            sh.stream_into(&backend, rel, src.as_mut(), size, MovePath::Prefetch, phys)
        {
            // placement reserved the bytes; a failed copy gives them
            // back (stream_into removed the partial device file)
            sh.accountant.credit(dev, size);
            return Err(e);
        }
        let gen = sh.next_gen();
        sh.insert_placed(
            rel,
            Entry::new(Some(dev), size, true, gen, 0).with_pfs_physical(phys),
        );
        Ok(true)
    }

    /// Open a writer handle on a mount-relative path: place at open,
    /// debit space as the file grows, defer mgmt to the last close.
    ///
    /// Eligibility at open uses the declared `p·F` floor; a stream that
    /// then outgrows its device spills mid-stream to the PFS (see
    /// [`SeaFile::spill`]) and continues instead of failing.
    fn open_writer(&self, rel: &str, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        let sh = &self.shared;
        if mode == OpenMode::ReadWrite {
            // update an existing copy in place: the entry (and its
            // epoch) is shared with any other open writers
            let gen = sh.next_gen();
            let found = sh.registry.update(rel, |e| {
                e.writers += 1;
                e.generation = gen;
                if e.dev.is_some() {
                    e.flushed = false; // contents are about to change
                    e.pfs_physical = None; // any PFS replica is stale
                }
                (e.dev, e.epoch)
            });
            if let Some((dev, epoch)) = found {
                let opened = match dev {
                    Some(d) => sh.backend(d).open(Path::new(rel), OpenMode::ReadWrite),
                    // spilled mid-stream: the live copy is on the PFS
                    None => sh.pfs.open(Path::new(rel), OpenMode::ReadWrite),
                };
                match opened {
                    Ok(file) => {
                        sh.engine.on_access(rel, Access::Write);
                        return Ok(Box::new(SeaFile {
                            shared: sh.clone(),
                            rel: rel.to_string(),
                            dev,
                            epoch,
                            append: false,
                            reader: false,
                            quiet: false,
                            file,
                        }));
                    }
                    Err(e) => {
                        rollback_join(sh, rel, epoch);
                        return Err(e);
                    }
                }
            }
            if sh.pfs.exists(Path::new(rel)) {
                // no local copy: update the PFS-resident file in place.
                // In-place writers patch arbitrary offsets, so a
                // compressed replica must be rewritten raw first
                // (no-op for plain files; replicas outlive the mount
                // that compressed them, so this never gates on the
                // current codec setting).
                sh.materialize_raw_on_pfs(rel)?;
                sh.engine.on_access(rel, Access::Write);
                return sh.pfs.open(Path::new(rel), mode);
            }
            // brand-new file: fall through to placement
        }
        self.drop_local(rel)?;
        // eligibility uses the p·F floor; actual bytes are debited as
        // the handle grows the file
        let pick = sh
            .engine
            .place(sh.ectx(), PlaceCtx { rel, size: 0, prefetch: false });
        match pick {
            Placement::Device(dev) => {
                trace::instant("place", "placement", "device", 0);
                let file = sh.backend(dev).open(Path::new(rel), OpenMode::Write)?;
                let gen = sh.next_gen();
                sh.insert_placed(rel, Entry::new(Some(dev), 0, false, gen, 1));
                Ok(Box::new(SeaFile {
                    shared: sh.clone(),
                    rel: rel.to_string(),
                    dev: Some(dev),
                    epoch: gen,
                    append: false,
                    reader: false,
                    quiet: false,
                    file,
                }))
            }
            Placement::Pfs => sh.pfs.open(Path::new(rel), OpenMode::Write),
        }
    }

    /// Open an append handle. Unlike `Write`/`ReadWrite`, concurrent
    /// appenders must *never* orphan each other, so create-vs-join is
    /// decided (and the backend file created) in a single shard-lock
    /// critical section.
    fn open_append(&self, rel: &str) -> Result<Box<dyn VfsFile>> {
        let sh = &self.shared;
        // pre-select in case we create; size 0 means nothing is debited,
        // so there is nothing to roll back if we end up joining
        let pick = match sh
            .engine
            .place(sh.ectx(), PlaceCtx { rel, size: 0, prefetch: false })
        {
            Placement::Device(d) => Some(d),
            Placement::Pfs => None,
        };
        enum How {
            Join(Option<DeviceRef>, u64),
            Created(DeviceRef, u64, Box<dyn VfsFile>),
            Pfs,
            Fail(Error),
        }
        let how = sh.registry.with_shard(rel, |m| match m.get_mut(rel) {
            Some(e) => {
                e.writers += 1;
                e.generation = sh.next_gen();
                if e.dev.is_some() {
                    e.flushed = false;
                    e.pfs_physical = None; // any PFS replica is stale
                }
                How::Join(e.dev, e.epoch)
            }
            None => {
                if sh.pfs.exists(Path::new(rel)) {
                    return How::Pfs;
                }
                let Some(dev) = pick else { return How::Pfs };
                // create the backend file here, under the shard lock:
                // a joiner arriving next already finds the entry and can
                // never be truncated by a racing creator
                match sh.backend(dev).open(Path::new(rel), OpenMode::Write) {
                    Ok(file) => {
                        let gen = sh.next_gen();
                        m.insert(rel.to_string(), Entry::new(Some(dev), 0, false, gen, 1));
                        How::Created(dev, gen, file)
                    }
                    Err(e) => How::Fail(e),
                }
            }
        });
        match how {
            How::Join(dev, epoch) => {
                let opened = match dev {
                    Some(d) => sh.backend(d).open(Path::new(rel), OpenMode::ReadWrite),
                    None => sh.pfs.open(Path::new(rel), OpenMode::ReadWrite),
                };
                match opened {
                    Ok(file) => {
                        sh.engine.on_access(rel, Access::Write);
                        Ok(Box::new(SeaFile {
                            shared: sh.clone(),
                            rel: rel.to_string(),
                            dev,
                            epoch,
                            append: true,
                            reader: false,
                            quiet: false,
                            file,
                        }))
                    }
                    Err(e) => {
                        rollback_join(sh, rel, epoch);
                        Err(e)
                    }
                }
            }
            How::Created(dev, gen, file) => Ok(Box::new(SeaFile {
                shared: sh.clone(),
                rel: rel.to_string(),
                dev: Some(dev),
                epoch: gen,
                append: true,
                reader: false,
                quiet: false,
                file,
            })),
            // no local entry: append to the PFS-resident file (the PFS
            // backend provides its own append atomicity). A compressed
            // replica cannot take in-place appends — rewrite it raw.
            How::Pfs => {
                if sh.pfs.exists(Path::new(rel)) {
                    sh.materialize_raw_on_pfs(rel)?;
                }
                sh.pfs.open(Path::new(rel), OpenMode::Append)
            }
            How::Fail(e) => Err(e),
        }
    }

    /// Open a reader-mode [`SeaFile`] for `rel`: preads refuse writes,
    /// skip writer accounting, and the registry hooks (`map_sync` /
    /// `map_identity`) let read views follow a spill and share frames.
    /// Heats the engine once at open; `quiet` additionally suppresses
    /// the per-`pread` heat — used by the chunked whole-file
    /// [`Vfs::read`] so one `read()` call counts exactly one access
    /// however many chunks it streams.
    fn open_reader(&self, rel: String, quiet: bool) -> Result<SeaFile> {
        self.shared.engine.on_access(&rel, Access::Read);
        let (file, dev, epoch) = match self.shared.registry.get(&rel) {
            Some(e) => match e.dev {
                Some(d) => {
                    match self.shared.backend(d).open(Path::new(&rel), OpenMode::Read) {
                        Ok(f) => (f, Some(d), e.epoch),
                        // evicted between lookup and open: the flush
                        // that preceded eviction put a PFS copy there
                        Err(Error::NotFound(_)) => {
                            (self.shared.open_pfs_reader(&rel)?, None, e.epoch)
                        }
                        Err(err) => return Err(err),
                    }
                }
                // spilled: the live copy is on the PFS
                None => (self.shared.open_pfs_reader(&rel)?, None, e.epoch),
            },
            // untracked: a PFS-resident file (epoch 0). `open_pfs_reader`
            // probes for a compressed container and, when it finds one,
            // returns a seekable decoding view — reads always see
            // logical bytes, whichever codec wrote the replica.
            None => (self.shared.open_pfs_reader(&rel)?, None, 0),
        };
        Ok(SeaFile {
            shared: self.shared.clone(),
            rel,
            dev,
            epoch,
            append: false,
            reader: true,
            quiet,
            file,
        })
    }

    /// `unlink` body; caller holds the per-file flush lock for `rel`.
    fn unlink_locked(&self, path: &Path, rel: &str) -> Result<()> {
        let had_local = self.shared.registry.contains(rel);
        self.drop_local(rel)?;
        // also remove a flushed/PFS copy if present
        let on_pfs = self.shared.pfs.exists(Path::new(rel));
        if on_pfs {
            self.shared.pfs.unlink(Path::new(rel))?;
        }
        if had_local || on_pfs {
            // the path is gone: the engine forgets its heat and any
            // queued promotion candidacy, so dead paths neither hold
            // heat-map slots nor win stale promotions
            self.shared.engine.on_removed(rel);
            Ok(())
        } else {
            Err(Error::NotFound(path.to_path_buf()))
        }
    }

    /// `rename` body; caller holds the per-file flush lock for `rf`.
    fn rename_locked(&self, rf: &str, rt: &str) -> Result<()> {
        // open writer handles key their registry updates by the old
        // path; moving the entry out from under them would strand their
        // writer counts, so refuse while any are open
        let moved = self.shared.registry.remove_if(rf, |e| e.writers == 0);
        match moved {
            Some(e) => {
                // rename-over-existing replaces the destination: drop its
                // local copy (crediting its space) before the insert, or
                // the old entry's bytes leak from the ledger forever
                self.drop_local(rt)?;
                let (dev, flushed, gen, size) = (e.dev, e.flushed, e.generation, e.size);
                self.shared.registry.insert(rt.to_string(), e);
                if let Some(d) = dev {
                    self.shared
                        .backend(d)
                        .rename(Path::new(rf), Path::new(rt))?;
                }
                if flushed && self.shared.pfs.exists(Path::new(rf)) {
                    // a Copy-mode flush (or a spill) left a PFS copy
                    // under the old name — move it along too
                    self.shared.pfs.rename(Path::new(rf), Path::new(rt))?;
                } else if !flushed {
                    // pending mgmt enqueued under the old name was
                    // dropped with the key; re-enqueue for the new
                    let decisions = self
                        .shared
                        .engine
                        .on_close(CloseCtx { rel: rt, dev, size });
                    self.shared.enqueue_close(rt, gen, &decisions);
                }
                // heat / promotion candidacy follows the new name
                self.shared.engine.on_renamed(rf, rt);
                Ok(())
            }
            None if self.shared.registry.contains(rf) => Err(Error::InvalidArg(format!(
                "rename {rf:?}: open writer handles pin the old name"
            ))),
            None => {
                self.shared.pfs.rename(Path::new(rf), Path::new(rt))?;
                // a pre-existing local copy under the destination name
                // would shadow the renamed PFS file on reads — drop it
                self.drop_local(rt)?;
                self.shared.engine.on_renamed(rf, rt);
                Ok(())
            }
        }
    }

    /// Remove the local copy of `rel` if any, crediting its space.
    fn drop_local(&self, rel: &str) -> Result<()> {
        let sh = &self.shared;
        let old = sh.registry.remove(rel);
        if let Some(e) = old {
            if let Some(d) = e.dev {
                match sh.backend(d).unlink(Path::new(rel)) {
                    Ok(()) | Err(Error::NotFound(_)) => {}
                    Err(err) => return Err(err),
                }
                sh.credit_and_notify(d, e.size);
            }
            // dev == None (spilled): the bytes live on the PFS and the
            // ledger was credited at spill time — nothing local to drop
        }
        Ok(())
    }
}

/// Undo a failed writer join: drop the writer count and, when that
/// leaves the entry idle, re-enqueue management. The join already
/// bumped the generation (cancelling any queued job) and cleared
/// `flushed`, and the failed open returns no handle whose close would
/// re-enqueue — without this the file would be stranded on its device,
/// never flushed and never evicted.
fn rollback_join(sh: &Arc<Shared>, rel: &str, epoch: u64) {
    let regen = sh
        .registry
        .update(rel, |en| {
            if en.epoch != epoch {
                return None;
            }
            en.writers = en.writers.saturating_sub(1);
            if en.writers == 0 && en.dev.is_some() {
                Some((en.generation, en.dev, en.size))
            } else {
                None
            }
        })
        .flatten();
    if let Some((gen, dev, size)) = regen {
        let decisions = sh.engine.on_close(CloseCtx { rel, dev, size });
        sh.enqueue_close(rel, gen, &decisions);
    }
}

/// What a writer handle should do next, decided under the shard lock.
enum Step {
    /// Reservation done (or not needed): write at this offset.
    Go(u64),
    /// Like [`Step::Go`], but the write targets the device copy: the
    /// entry's `pending` count was incremented and the handle must call
    /// `complete_device_write` once the backend I/O returns (write
    /// serials — a concurrent spill drains and re-copies these).
    GoTracked(u64),
    /// Entry replaced or gone and the handle is appending: write at the
    /// orphaned inode's own end (resolved lazily — it needs an fstat).
    Orphan,
    /// Device exhausted: ask the engine for pressure relief (spill a
    /// victim, or the writer itself), then retry.
    Spill {
        /// Additional bytes the reservation needed.
        need: u64,
    },
    /// Another handle spilled this entry: reopen on the PFS, retry.
    Reopen,
    /// A spill of this entry is flipping right now: yield and retry.
    Busy,
}

/// Handle on a placed file. Writers grow the registry entry (and the
/// space ledger) as bytes land, spill to the PFS when their device
/// fills, and trigger deferred management when the last writer closes.
/// Read opens get the same wrapper in `reader` mode: no writer count,
/// no accounting — but preads heat the placement engine, and the
/// registry hooks (`map_sync` / `map_identity`) let read views follow
/// a spill and share page frames with every other handle of the file.
struct SeaFile {
    shared: Arc<Shared>,
    rel: String,
    /// Device this handle currently targets; `None` once it follows a
    /// spill onto the PFS (or was opened against the PFS copy).
    dev: Option<DeviceRef>,
    /// Epoch of the entry this handle belongs to (for writers, where
    /// its writer count lives); a mismatch means the entry was replaced
    /// (`drop_local`) and this handle's file is an orphaned inode —
    /// I/O still lands there, but registry and ledger must not be
    /// touched. Readers of an untracked (PFS-only) file carry epoch 0.
    epoch: u64,
    /// Append handle: offsets are resolved from the entry's size under
    /// the shard lock; the caller's offset is ignored.
    append: bool,
    /// Read-only handle: writes are refused, close-time management and
    /// the writer count are skipped entirely.
    reader: bool,
    /// Suppress per-`pread` heat. The whole-file [`Vfs::read`]
    /// convenience streams through a reader handle in
    /// `chunk_bytes`-sized preads; heating on every chunk would make
    /// one `read()` of a large file count `size / chunk_bytes`
    /// accesses — inflating heat in proportion to file size and
    /// skewing `TemperatureEngine` victim elections — so that path
    /// heats once at open and quiets the per-chunk heat.
    quiet: bool,
    file: Box<dyn VfsFile>,
}

impl SeaFile {
    /// The latency-histogram metric for `op` on this handle's current
    /// layer: the tier of the device it targets, or the PFS once it
    /// followed a spill (or opened against the PFS copy).
    fn io_metric(&self, op: IoOp) -> Metric {
        Metric::io(op, self.dev.map(|d| self.shared.hierarchy.info(d).tier))
    }

    /// Resolve the write offset (`off = None` for append) and reserve
    /// registry/ledger space for `len` bytes, atomically under the
    /// entry's shard lock. Size update and ledger debit happen
    /// together, so a failed reservation never has to roll back a size
    /// a concurrent handle extended in the meantime.
    fn reserve(&mut self, off: Option<u64>, len: u64) -> Result<Step> {
        // superseded handles write to their orphaned inode without
        // accounting; an orphaned *appender* resolves its offset lazily
        // (fstat) so the hot join path pays no extra syscall
        let orphan_step = || match off {
            Some(o) => Step::Go(o),
            None => Step::Orphan,
        };
        let epoch = self.epoch;
        let on_pfs = self.dev.is_none();
        let sh = self.shared.clone();
        sh.registry
            .update(&self.rel, |e| -> Result<Step> {
                if e.epoch != epoch {
                    return Ok(orphan_step());
                }
                match e.dev {
                    None if !on_pfs => Ok(Step::Reopen),
                    None => {
                        // entry lives on the PFS: unbounded, track size
                        let off = off.unwrap_or(e.size);
                        let end = off + len;
                        if end > e.size {
                            e.size = end;
                        }
                        Ok(Step::Go(off))
                    }
                    Some(d) => {
                        if e.migrating {
                            return Ok(Step::Busy);
                        }
                        let off = off.unwrap_or(e.size);
                        let end = off + len;
                        if end > e.size {
                            let need = end - e.size;
                            if !sh.accountant.try_debit(d, need, 0) {
                                return Ok(Step::Spill { need });
                            }
                            e.size = end;
                        }
                        e.pending += 1;
                        Ok(Step::GoTracked(off))
                    }
                }
            })
            .unwrap_or_else(|| Ok(orphan_step()))
    }

    /// Record a completed device write: drops the in-flight count,
    /// bumps the entry's write serial, and — when a spill has armed its
    /// log — remembers the range so the spill re-copies it before the
    /// flip. Called after the backend I/O returns (success or not: on
    /// error the device copy is still the source of truth, so a
    /// conservative re-copy is harmless).
    fn complete_device_write(&self, off: u64, len: u64) {
        let epoch = self.epoch;
        let _ = self.shared.registry.update(&self.rel, |e| {
            if e.epoch != epoch {
                return;
            }
            e.pending = e.pending.saturating_sub(1);
            e.serial += 1;
            if e.recopy_armed {
                e.recopy.push((off, len));
            }
        });
    }

    /// Device exhausted: let the engine decide who makes room. Victim
    /// spills free space so this writer can stay on its device; when
    /// the engine (or a failed victim round) says so, the writer itself
    /// migrates to the PFS.
    fn relieve_pressure(&mut self, need: u64) -> Result<()> {
        let sh = self.shared.clone();
        let Some(dev) = self.dev else {
            return Ok(()); // already following a spill: retry reserves
        };
        // the registry-wide snapshot is only paid for engines that
        // actually pick victims (the paper engine always spills self)
        let residents = if sh.engine.wants_residents() {
            sh.residents()
        } else {
            Vec::new()
        };
        let decisions = sh.engine.on_pressure(
            sh.ectx(),
            PressureCtx { rel: &self.rel, dev, need, residents: &residents },
        );
        let mut spill_self = decisions.is_empty();
        let mut progressed = false;
        for d in &decisions {
            match d {
                Decision::SpillSelf => spill_self = true,
                Decision::SpillVictim { rel } if *rel != self.rel => {
                    if sh.spill_victim(rel) {
                        progressed = true;
                    }
                }
                _ => {}
            }
        }
        if spill_self || !progressed {
            // no victim made room: guarantee progress by migrating
            self.spill()?;
        }
        Ok(())
    }

    /// Mid-stream spill: migrate the partial file from its device to
    /// the PFS and switch this handle over. Runs under the per-file
    /// flush lock (serialising with the flush pool, unlink, rename and
    /// other spills). The bulk copy runs **outside** the shard lock
    /// (the shard stays live for unrelated files); per-handle write
    /// serials detect sibling writes that land mid-copy so their ranges
    /// are re-copied before the flip (see [`SeaFile::migrate_to_pfs`]).
    /// Writer counts are preserved: sibling handles keep their epoch
    /// and observe the relocation on their next reservation
    /// ([`Step::Reopen`]).
    fn spill(&mut self) -> Result<()> {
        let sh = self.shared.clone();
        let lk = sh.flush_lock(&self.rel);
        let migrated = {
            let _guard = lk.lock().expect("flush lock poisoned");
            self.migrate_to_pfs()
        };
        // drop our Arc before releasing, or the map entry (strong count
        // still >= 2) is never reclaimed and leaks per spilled file
        drop(lk);
        sh.release_flush_lock(&self.rel);
        match migrated? {
            Some((out, dev, size)) => {
                self.file = out;
                self.dev = None;
                sh.counters.lock().expect("counters poisoned").self_spills += 1;
                sh.notify_freed(dev, size);
                Ok(())
            }
            // superseded or already spilled: the retry loop re-reserves
            // and takes the orphan / reopen path as appropriate
            None => Ok(()),
        }
    }

    /// Spill body; caller holds the per-file flush lock. Four phases:
    ///
    /// 1. **Arm** (shard lock): start logging completed write ranges
    ///    into the entry's `recopy` list, snapshot size and serial.
    /// 2. **Bulk copy** (no shard lock): stream the device copy to the
    ///    PFS; siblings keep writing, their completions are logged.
    /// 3. **Block** (shard lock): set `migrating` — new reservations
    ///    get [`Step::Busy`].
    /// 4. **Drain + flip** (shard lock): wait for in-flight writes to
    ///    complete, re-copy every logged range (serial mismatch =
    ///    sibling write landed mid-copy), then flip the entry to the
    ///    PFS, crediting the device.
    ///
    /// Returns the PFS handle plus `(device, bytes)` credited, or
    /// `None` when superseded (entry replaced or already spilled).
    fn migrate_to_pfs(&mut self) -> Result<Option<(Box<dyn VfsFile>, DeviceRef, u64)>> {
        let sh = self.shared.clone();
        let epoch = self.epoch;
        let rel = self.rel.clone();
        // phase 1: arm the write-serial log
        let armed = sh
            .registry
            .update(&rel, |e| {
                if e.epoch != epoch || e.migrating || e.recopy_armed {
                    return None;
                }
                let dev = e.dev?;
                e.recopy_armed = true;
                e.recopy.clear();
                Some((dev, e.size, e.serial))
            })
            .flatten();
        let Some((dev, size0, serial0)) = armed else {
            return Ok(None);
        };
        // flight-recorder span covering phases 2–4 (bulk copy through
        // drain + flip): a mid-stream spill is the writer-observed cost
        let mut sp = trace::span("spill", "mgmt", "pressure");
        sp.bytes(size0);
        // phase 2: bulk copy without the shard lock, streamed through
        // the DataMover — device read-ahead overlaps the PFS
        // write-behind, and peak memory is chunk_bytes × copy_window
        // however large the partial file grew. A short copy is fine:
        // a reserved-but-unwritten sparse tail is zero-filled by the
        // flip's set_len.
        let mut out = match sh.pfs.open(Path::new(&rel), OpenMode::Write) {
            Ok(f) => f,
            Err(err) => {
                disarm_spill(&sh, &rel, epoch);
                return Err(err);
            }
        };
        if let Err(err) = sh
            .mover_to(sh.pfs.as_ref(), MovePath::Spill)
            .copy(self.file.as_mut(), out.as_mut(), size0)
        {
            disarm_spill(&sh, &rel, epoch);
            return Err(err);
        }
        // phase 3: stop new reservations
        let alive = sh
            .registry
            .update(&rel, |e| {
                if e.epoch != epoch {
                    return false;
                }
                e.migrating = true;
                true
            })
            .unwrap_or(false);
        if !alive {
            // replaced mid-copy; the flags died with the old entry
            return Ok(None);
        }
        // phase 4: drain in-flight writes, re-copy their ranges, flip
        enum Flip {
            Wait,
            Gone,
            Done(u64),
        }
        let chunk = sh.mover_cfg.chunk_bytes;
        loop {
            let file = &mut self.file;
            let out_ref = &mut out;
            let res = sh.registry.update(&rel, |e| -> Result<Flip> {
                if e.epoch != epoch {
                    return Ok(Flip::Gone);
                }
                if e.pending > 0 {
                    return Ok(Flip::Wait);
                }
                debug_assert_eq!(
                    e.serial,
                    serial0 + e.recopy.len() as u64,
                    "every completion since arming must be logged"
                );
                if e.serial != serial0 {
                    // sibling writes landed during the bulk copy:
                    // re-copy exactly the affected ranges (a logged
                    // whole-file truncate is `(0, u64::MAX)` and clamps
                    // to the entry size). Chunked, synchronous: this
                    // runs under the shard lock, so no reader thread.
                    for &(off, rlen) in e.recopy.iter() {
                        if off >= e.size {
                            continue;
                        }
                        let len = rlen.min(e.size - off);
                        let n = copy_range(
                            file.as_mut(),
                            out_ref.as_mut(),
                            off,
                            len,
                            chunk,
                            Some(&sh.mover),
                        )?;
                        // recopied ranges are spill traffic too
                        // (raw copy: logical and physical are equal)
                        sh.mover.record(MovePath::Spill, n);
                        sh.mover.record_physical(MovePath::Spill, n);
                    }
                }
                // zero-fill any sparse tail up to the reserved size
                out_ref.set_len(e.size)?;
                let _ = sh.backend(dev).unlink(Path::new(&rel));
                sh.accountant.credit(dev, e.size);
                let freed = e.size;
                e.dev = None;
                e.flushed = true; // the PFS copy IS the file now
                e.pfs_physical = None; // self-spills always land raw
                e.generation = sh.next_gen(); // stand down stale jobs
                e.migrating = false;
                e.recopy_armed = false;
                e.recopy.clear();
                Ok(Flip::Done(freed))
            });
            match res {
                None => return Ok(None), // entry vanished
                Some(Ok(Flip::Gone)) => return Ok(None),
                Some(Ok(Flip::Wait)) => {
                    // in-flight sibling writes still draining
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                Some(Ok(Flip::Done(freed))) => return Ok(Some((out, dev, freed))),
                Some(Err(err)) => {
                    disarm_spill(&sh, &rel, epoch);
                    return Err(err);
                }
            }
        }
    }

    /// Follow a sibling handle's spill: swap this handle's file for a
    /// PFS one (readers reopen read-only).
    fn reopen_on_pfs(&mut self) -> Result<()> {
        let mode = if self.reader { OpenMode::Read } else { OpenMode::ReadWrite };
        self.file = self.shared.pfs.open(Path::new(&self.rel), mode)?;
        self.dev = None;
        Ok(())
    }
}

/// Abort a spill attempt: clear the migration flags so writers resume
/// normally (the entry stays device-resident).
fn disarm_spill(sh: &Shared, rel: &str, epoch: u64) {
    let _ = sh.registry.update(rel, |e| {
        if e.epoch == epoch {
            e.recopy_armed = false;
            e.migrating = false;
            e.recopy.clear();
        }
    });
}


impl VfsFile for SeaFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        if self.reader && !self.quiet {
            // reads heat the file for the TemperatureEngine just like
            // writes do — a hot reader must outlive a cold writer in
            // victim elections (writer handles already heat on pwrite).
            // `quiet` readers (the chunked whole-file `Vfs::read`)
            // heated once at open instead of once per chunk.
            self.shared.engine.on_access(&self.rel, Access::Read);
        }
        let t = Timer::start();
        let n = self.file.pread(buf, off)?;
        t.stop(self.io_metric(IoOp::Pread));
        Ok(n)
    }

    fn lease_fd(&self) -> Option<std::fs::File> {
        // Delegate to the resident replica's handle: a dir-device (or
        // plain-RealFs PFS) replica surfaces its O_RDONLY fd; striped
        // or compressed replicas decline. Reader handles only — the
        // daemon pairs the fd with the map generation, and a spill's
        // generation bump revokes it while the orphaned inode keeps
        // serving in-flight reads a consistent snapshot. Note leased
        // reads bypass `on_access` heat; the trade is deliberate (the
        // data plane's whole point is zero daemon involvement).
        if self.reader {
            self.file.lease_fd()
        } else {
            None
        }
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        if self.reader {
            return Err(Error::InvalidArg(format!(
                "{:?}: write through a read-only sea handle",
                self.rel
            )));
        }
        if data.is_empty() {
            return Ok(0);
        }
        let want = if self.append { None } else { Some(off) };
        // timed from first reservation attempt: spill relief and
        // busy-waits are part of the latency a writer observes
        let t = Timer::start();
        loop {
            match self.reserve(want, data.len() as u64)? {
                Step::Go(at) => {
                    let n = self.file.pwrite(data, at)?;
                    t.stop(self.io_metric(IoOp::Pwrite));
                    return Ok(n);
                }
                Step::GoTracked(at) => {
                    let r = self.file.pwrite(data, at);
                    self.complete_device_write(at, data.len() as u64);
                    if r.is_ok() {
                        t.stop(self.io_metric(IoOp::Pwrite));
                    }
                    return r;
                }
                Step::Orphan => {
                    let at = self.file.len()?;
                    let n = self.file.pwrite(data, at)?;
                    t.stop(self.io_metric(IoOp::Pwrite));
                    return Ok(n);
                }
                Step::Spill { need } => self.relieve_pressure(need)?,
                Step::Reopen => self.reopen_on_pfs()?,
                Step::Busy => {
                    // a spill of this entry is mid-flight (possibly a
                    // long bulk copy): back off instead of burning a
                    // core on yield_now
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            }
        }
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        if self.reader {
            return Err(Error::InvalidArg(format!(
                "{:?}: truncate through a read-only sea handle",
                self.rel
            )));
        }
        loop {
            let epoch = self.epoch;
            let on_pfs = self.dev.is_none();
            let sh = self.shared.clone();
            let mut freed: Option<(DeviceRef, u64)> = None;
            // size update and ledger adjustment are atomic under the
            // shard lock, like reserve
            let step = sh
                .registry
                .update(&self.rel, |e| -> Result<Step> {
                    if e.epoch != epoch {
                        return Ok(Step::Go(0)); // superseded: no accounting
                    }
                    match e.dev {
                        None if !on_pfs => Ok(Step::Reopen),
                        None => {
                            e.size = len;
                            Ok(Step::Go(0))
                        }
                        Some(d) => {
                            // truncation affects the whole file: refuse
                            // to interleave with a spill's copy phases
                            if e.migrating || e.recopy_armed {
                                return Ok(Step::Busy);
                            }
                            if len > e.size {
                                let need = len - e.size;
                                if !sh.accountant.try_debit(d, need, 0) {
                                    return Ok(Step::Spill { need });
                                }
                            } else {
                                sh.accountant.credit(d, e.size - len);
                                freed = Some((d, e.size - len));
                            }
                            e.size = len;
                            e.pending += 1;
                            Ok(Step::GoTracked(0))
                        }
                    }
                })
                .unwrap_or(Ok(Step::Go(0)))?;
            if let Some((d, n)) = freed {
                if n > 0 {
                    sh.notify_freed(d, n);
                }
            }
            match step {
                Step::Go(_) | Step::Orphan => return self.file.set_len(len),
                Step::GoTracked(_) => {
                    let r = self.file.set_len(len);
                    // a truncate has no single range: log a whole-file
                    // re-copy in case a spill armed mid-flight
                    self.complete_device_write(0, u64::MAX);
                    return r;
                }
                Step::Spill { need } => self.relieve_pressure(need)?,
                Step::Reopen => self.reopen_on_pfs()?,
                Step::Busy => {
                    // a spill of this entry is mid-flight (possibly a
                    // long bulk copy): back off instead of burning a
                    // core on yield_now
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            }
        }
    }

    fn fsync(&mut self) -> Result<()> {
        let t = Timer::start();
        self.file.fsync()?;
        t.stop(self.io_metric(IoOp::Fsync));
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        self.file.len()
    }

    /// The deliberate PageCache hook: mapped views over a Sea handle —
    /// reader and writer alike — follow the registry. The returned
    /// generation bumps on every (re)placement and spill, so a view
    /// invalidates (and transparently re-faults) its pages instead of
    /// serving stale device bytes; when a sibling's mid-stream spill
    /// relocated the file, the handle is re-pointed at the PFS replica
    /// *before* the view writes dirty pages back or faults fresh ones.
    fn map_sync(&mut self) -> Result<u64> {
        let epoch = self.epoch;
        let state = self
            .shared
            .registry
            .update(&self.rel, |e| {
                if e.epoch != epoch {
                    return None;
                }
                Some((e.dev.is_none(), e.generation))
            })
            .flatten();
        match state {
            Some((entry_on_pfs, gen)) => {
                if entry_on_pfs && self.dev.is_some() {
                    // the device inode this handle holds was orphaned
                    // by the spill: fault and write back through the
                    // live PFS copy, never the stale device bytes
                    self.reopen_on_pfs()?;
                }
                Ok(gen)
            }
            // superseded (entry replaced or retired): the orphan inode
            // stays this view's source and no generation moves again
            None => Ok(0),
        }
    }

    /// Page faults feed the placement engine: a mapped read heats the
    /// file for the `TemperatureEngine` exactly like a handle read.
    fn note_map_fault(&mut self, off: u64, len: u64) {
        let _ = (off, len);
        self.shared.engine.on_access(&self.rel, Access::Read);
    }

    /// Frame-sharing identity: mount (the `Shared` allocation is as
    /// unique and stable as the mount itself) + path + entry epoch.
    /// Every handle of one placed file agrees on it whatever inode it
    /// currently targets, so views share frames across readers,
    /// writers and spill relocations; the epoch keeps a superseded
    /// handle (orphaned inode) from sharing frames with a recreated
    /// file of the same name.
    fn map_identity(&self) -> Option<u128> {
        let mount = Arc::as_ptr(&self.shared) as u64;
        Some(crate::vfs::pages::identity_hash(&[
            &mount.to_le_bytes(),
            self.rel.as_bytes(),
            &self.epoch.to_le_bytes(),
        ]))
    }
}

impl Drop for SeaFile {
    fn drop(&mut self) {
        if self.reader {
            return; // readers hold no writer count, owe no management
        }
        let sh = &self.shared;
        // Membership is by entry identity (epoch), not content
        // generation: a concurrent in-place writer bumps the generation
        // but shares this entry, so the count must still drop; a replaced
        // entry (drop_local) took this handle's count with it, so the
        // superseding entry must not be touched. The last closer enqueues
        // with the entry's *current* generation so the job matches
        // whatever the final writer left behind.
        let mgmt = sh
            .registry
            .update(&self.rel, |e| {
                if e.epoch != self.epoch {
                    return None; // superseded by a newer placement
                }
                e.writers = e.writers.saturating_sub(1);
                if e.writers == 0 {
                    Some((e.generation, e.dev, e.size))
                } else {
                    None
                }
            })
            .flatten();
        match mgmt {
            Some((gen, Some(dev), size)) => {
                let decisions = sh
                    .engine
                    .on_close(CloseCtx { rel: &self.rel, dev: Some(dev), size });
                sh.enqueue_close(&self.rel, gen, &decisions);
            }
            Some((_gen, None, size)) => {
                // spilled mid-stream: the file already lives durably on
                // the PFS — retire the entry instead of flushing. An
                // evict-without-flush (Remove-mode) file was never meant
                // to be persisted, so drop its PFS copy too (under the
                // per-file flush lock, like unlink, so it can't race a
                // flush of a successor).
                let decisions = sh
                    .engine
                    .on_close(CloseCtx { rel: &self.rel, dev: None, size });
                let (flush, evict) = flush_evict_flags(&self.rel, &decisions);
                let lk = sh.flush_lock(&self.rel);
                {
                    let _guard = lk.lock().expect("flush lock poisoned");
                    let retired = sh.registry.remove_if(&self.rel, |e| {
                        e.epoch == self.epoch && e.writers == 0 && e.dev.is_none()
                    });
                    if retired.is_some() && evict && !flush {
                        let _ = sh.pfs.unlink(Path::new(&self.rel));
                    }
                }
                drop(lk);
                sh.release_flush_lock(&self.rel);
            }
            None => {}
        }
    }
}

fn flush_worker(sh: Arc<Shared>, rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    loop {
        // hold the inbox lock only while dequeuing; processing overlaps
        // across the pool
        let job = {
            let guard = rx.lock().expect("rx poisoned");
            guard.recv()
        };
        let Ok(job) = job else { break };
        process_job(&sh, &job);
        let mut p = sh.pending.lock().expect("pending poisoned");
        *p -= 1;
        sh.idle.notify_all();
    }
}

fn process_job(sh: &Shared, job: &Job) {
    // serialise per file so two generations never interleave on the PFS
    let rel = job.rel().to_string();
    let lk = sh.flush_lock(&rel);
    {
        let _file_guard = lk.lock().expect("flush lock poisoned");
        match job {
            Job::Mgmt { rel, gen, flush, evict } => {
                run_mgmt(sh, rel, *gen, *flush, *evict, MovePath::Flush)
            }
            Job::Promote { rel, tier } => run_promote(sh, rel, *tier),
        }
    }
    drop(lk);
    sh.release_flush_lock(&rel);
}

/// Execute a close-time management decision (flush and/or evict);
/// caller holds `rel`'s per-file flush lock. `class` attributes the
/// streamed bytes in the gauges (a victim spill is a flush+evict whose
/// traffic counts as spill).
fn run_mgmt(sh: &Shared, rel: &str, gen: u64, flush: bool, evict: bool, class: MovePath) {
    let Some(entry) = sh.registry.get(rel) else { return };
    // A newer write superseded this job (it enqueued its own), or a
    // writer handle is still open (its close will re-enqueue): stand down.
    if entry.generation != gen || entry.writers > 0 {
        return;
    }
    // A spilled entry already lives on the PFS: nothing to flush or
    // evict (the last close retires it).
    let Some(dev) = entry.dev else { return };
    if flush && !entry.flushed {
        // stream the device copy to the PFS in bounded chunks — no
        // whole-file Vec, whatever the file size
        let Ok(mut src) = sh.backend(dev).open(Path::new(rel), OpenMode::Read) else {
            return;
        };
        let Ok(src_len) = src.len() else { return };
        // a racing overwrite may have dropped and recreated the local
        // file mid-flush: only stream bytes whose size matches the entry
        if src_len != entry.size {
            return;
        }
        // flight-recorder span over the streamed copy (a victim spill
        // rides this same path with `class = Spill`)
        let mut sp = match class {
            MovePath::Spill => trace::span("spill", "mgmt", "victim"),
            _ => trace::span("flush", "mgmt", "close"),
        };
        sp.bytes(src_len);
        // OST-aware gate: cap in-flight flushes per PFS member (every
        // member a stripe-mode file touches holds a slot). On failure,
        // stream_into removes the partial destination — a stale prior
        // replica (the entry reopened for write, so any old PFS bytes
        // were already outdated) becomes cleanly absent instead of
        // silently truncated.
        let wrote = {
            let _slots = sh.pfs_slots_for(rel, src_len);
            sh.stream_into(&sh.pfs, rel, src.as_mut(), src_len, class, None)
        };
        let Ok(physical) = wrote else { return };
        // remember the replica's physical footprint iff the codec ran
        // (it shrank the copy or at least framed it); a raw replica
        // reports None so readers skip the probe
        let pfs_physical =
            if sh.encodes_pfs() && physical != src_len { Some(physical) } else { None };
        let confirmed = sh
            .registry
            .update(rel, |e| {
                if e.generation == gen {
                    e.flushed = true;
                    e.pfs_physical = pfs_physical;
                    true
                } else {
                    false
                }
            })
            .unwrap_or(false);
        if !confirmed {
            return; // superseded mid-flush: don't count, don't evict
        }
        sh.counters.lock().expect("counters poisoned").flushes += 1;
    }
    if evict {
        // Evict-without-flush files are dropped unconditionally (the
        // user declared them disposable); flush-then-evict (Move) files
        // must have been flushed first. Either way the generation must
        // still match.
        let removed = sh.registry.remove_if(rel, |e| {
            e.generation == gen && e.writers == 0 && (!flush || e.flushed)
        });
        if let Some(e) = removed {
            if let Some(d) = e.dev {
                let _ = sh.backend(d).unlink(Path::new(rel));
                sh.counters.lock().expect("counters poisoned").evictions += 1;
                trace::instant("evict", "mgmt", if flush { "moved" } else { "disposable" }, e.size);
                sh.credit_and_notify(d, e.size);
            }
        }
    }
}

/// Execute a `Promote` decision: pull a PFS-resident file back onto a
/// device of the requested tier; caller holds `rel`'s per-file flush
/// lock. Best-effort — if the file re-acquired a local copy, vanished,
/// or the tier filled up in the meantime, the promotion is dropped.
fn run_promote(sh: &Shared, rel: &str, tier: u8) {
    if !sh.engine.approve_promote(rel) {
        return; // superseded (write-open / re-place) since emission
    }
    if sh.registry.contains(rel) {
        return; // already resident
    }
    // stream the PFS copy up in bounded chunks — no whole-file Vec.
    // A compressed replica arrives wrapped in a decoding reader, so
    // `size` is the file's logical length and the promoted device copy
    // is raw logical bytes (fast tiers never hold framed replicas).
    let Ok((mut src, size, phys)) = sh.open_pfs_source(rel) else { return };
    let mut sp = trace::span("promote", "mgmt", "heat");
    sp.bytes(size);
    for d in sh.hierarchy.tier_devices(tier) {
        let Some(backend) = sh.hierarchy.backend(d) else {
            continue;
        };
        // promotion is an opportunistic cache fill: it must fit, but
        // the p·F reservation floor does not apply
        if !sh.accountant.try_debit(d, size, size) {
            continue;
        }
        if sh
            .stream_into(backend, rel, src.as_mut(), size, MovePath::Promote, phys)
            .is_err()
        {
            sh.accountant.credit(d, size);
            continue;
        }
        let gen = sh.next_gen();
        // the PFS copy remains authoritative-equal: the entry starts
        // flushed, so a later evict never re-copies it
        let inserted = sh.registry.with_shard(rel, |m| {
            if m.contains_key(rel) {
                false
            } else {
                // the replica (possibly compressed) stays authoritative,
                // so the entry keeps its physical footprint on record
                m.insert(
                    rel.to_string(),
                    Entry::new(Some(d), size, true, gen, 0).with_pfs_physical(phys),
                );
                true
            }
        });
        if inserted {
            sh.counters.lock().expect("counters poisoned").promotions += 1;
        } else {
            // a writer re-created the file while we copied: roll back
            let _ = sh.backend(d).unlink(Path::new(rel));
            sh.accountant.credit(d, size);
        }
        return;
    }
}

impl Drop for SeaFs {
    fn drop(&mut self) {
        // closing the inbox lets the pool drain the queue and exit
        *self.shared.tx.lock().expect("tx poisoned") = None;
        for h in self.workers.lock().expect("workers poisoned").drain(..) {
            let _ = h.join();
        }
    }
}

impl Vfs for SeaFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        match self.rel_of(path) {
            None => self.shared.pfs.open(path, mode),
            Some(rel) => {
                // time the whole dispatch (placement decision + backend
                // open); the layer is whatever the registry says the
                // file landed on once the open completed
                let t = Timer::start();
                let f = match mode {
                    // wrap the backend handle in a reader-mode SeaFile:
                    // preads keep heating the engine, and the registry
                    // hooks (map_sync / map_identity) let read views
                    // follow a spill and share frames with writers —
                    // instead of pinning a raw inode across relocation
                    OpenMode::Read => {
                        Box::new(self.open_reader(rel.clone(), false)?) as Box<dyn VfsFile>
                    }
                    OpenMode::Append => self.open_append(&rel)?,
                    OpenMode::Write | OpenMode::ReadWrite => self.open_writer(&rel, mode)?,
                };
                if t.armed() {
                    let tier = self
                        .shared
                        .registry
                        .get(&rel)
                        .and_then(|e| e.dev)
                        .map(|d| self.shared.hierarchy.info(d).tier);
                    t.stop(Metric::io(IoOp::Open, tier));
                }
                Ok(f)
            }
        }
    }

    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        match self.rel_of(path) {
            None => self.shared.pfs.read(path),
            Some(rel) => {
                // stream through the handle path in mover-sized chunks:
                // the backend never materializes the file in a second
                // whole-file buffer on top of the returned Vec. The
                // reader is `quiet`: the open heats the engine once, so
                // one read() counts one access regardless of how many
                // chunks it streams (per-chunk heat would inflate heat
                // in proportion to file size)
                let mut f = self.open_reader(rel, true)?;
                let len = f.len()? as usize;
                let chunk = self.shared.mover_cfg.chunk_bytes.max(1);
                let mut out = vec![0u8; len];
                let mut done = 0usize;
                while done < len {
                    let want = chunk.min(len - done);
                    let n = f.pread(&mut out[done..done + want], done as u64)?;
                    if n == 0 {
                        // the file shrank mid-read: return what exists
                        out.truncate(done);
                        break;
                    }
                    done += n;
                }
                Ok(out)
            }
        }
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.write(path, data),
            Some(rel) => {
                if let Some((dev, gen)) = self.place_and_write(&rel, data)? {
                    let decisions = self.shared.engine.on_close(CloseCtx {
                        rel: &rel,
                        dev: Some(dev),
                        size: data.len() as u64,
                    });
                    self.shared.enqueue_close(&rel, gen, &decisions);
                }
                Ok(())
            }
        }
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.unlink(path),
            Some(rel) => {
                // serialise with the flush pool: an in-flight flush of
                // `rel` must finish (or stand down) before we decide
                // whether a PFS copy exists, or a completing flush could
                // recreate the file on the PFS after this unlink
                let lk = self.shared.flush_lock(&rel);
                let res = {
                    let _guard = lk.lock().expect("flush lock poisoned");
                    self.unlink_locked(path, &rel)
                };
                drop(lk);
                self.shared.release_flush_lock(&rel);
                res
            }
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.rel_of(path) {
            None => self.shared.pfs.exists(path),
            Some(rel) => {
                self.shared.registry.contains(&rel)
                    || self.shared.pfs.exists(Path::new(&rel))
            }
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        match self.rel_of(path) {
            None => self.shared.pfs.size(path),
            Some(rel) => match self.shared.registry.get(&rel) {
                // registry sizes are logical by construction
                Some(e) => Ok(e.size),
                // untracked PFS residents may be compressed replicas:
                // report what they decode to, not the container length
                None => self.shared.pfs_logical_size(&rel),
            },
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        match (self.rel_of(from), self.rel_of(to)) {
            (Some(rf), Some(rt)) => {
                // serialise with in-flight flushes of *both* names (a
                // completing job could otherwise leave a PFS copy under
                // `rf`, or recreate the replaced destination `rt`);
                // locks are taken in sorted order so two concurrent
                // renames can't deadlock
                let mut names = vec![rf.clone()];
                if rt != rf {
                    names.push(rt.clone());
                    names.sort();
                }
                let locks: Vec<_> =
                    names.iter().map(|n| self.shared.flush_lock(n)).collect();
                let res = {
                    let _guards: Vec<_> = locks
                        .iter()
                        .map(|l| l.lock().expect("flush lock poisoned"))
                        .collect();
                    self.rename_locked(&rf, &rt)
                };
                drop(locks);
                for n in &names {
                    self.shared.release_flush_lock(n);
                }
                res
            }
            (None, None) => self.shared.pfs.rename(from, to),
            _ => Err(Error::InvalidArg(
                "rename across the sea mount boundary is not supported".into(),
            )),
        }
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        match self.rel_of(path) {
            None => self.shared.pfs.readdir(path),
            Some(rel) => {
                let mut names: Vec<String> = self
                    .shared
                    .pfs
                    .readdir(Path::new(&rel))
                    .unwrap_or_default();
                let prefix = if rel.is_empty() { String::new() } else { format!("{rel}/") };
                for key in self.shared.registry.keys() {
                    if let Some(rest) = key.strip_prefix(&prefix) {
                        if !rest.is_empty() && !rest.contains('/') {
                            names.push(rest.to_string());
                        }
                    }
                }
                names.sort();
                names.dedup();
                Ok(names)
            }
        }
    }

    fn sync_mgmt(&self) -> Result<()> {
        let mut p = self.shared.pending.lock().expect("pending poisoned");
        while *p > 0 {
            p = self.shared.idle.wait(p).expect("pending poisoned");
        }
        Ok(())
    }

    fn page_cache(&self) -> Option<Arc<PageCache>> {
        Some(self.shared.pages.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{KIB, MIB};
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;
    use crate::vfs::{RateLimitedFs, StripedFs};

    fn mount_cfg(root: &Path, pfs: Arc<dyn Vfs>, rules: RuleSet, tmpfs_cap: u64) -> SeaFs {
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![
                DeviceSpec::dir(root.join("tmpfs"), 0, tmpfs_cap).unwrap(),
                DeviceSpec::dir(root.join("disk0"), 1, 100 * MIB).unwrap(),
                DeviceSpec::dir(root.join("disk1"), 1, 100 * MIB).unwrap(),
            ],
            pfs,
            max_file_size: MIB,
            parallel_procs: 2,
            rules,
            seed: 7,
            tuning: SeaTuning::default(),
        })
        .unwrap()
    }

    fn mount(rules: RuleSet, tmpfs_cap: u64) -> (SeaFs, PathBuf, Arc<RealFs>) {
        let root = scratch("seafs");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = mount_cfg(&root, pfs.clone(), rules, tmpfs_cap);
        (sea, root, pfs)
    }

    /// The acceptance stack: SeaFs over a rate-limited striped PFS.
    fn mount_striped(rules: RuleSet, tmpfs_cap: u64) -> (SeaFs, PathBuf, Arc<dyn Vfs>) {
        let root = scratch("seafs_striped");
        let dirs: Vec<PathBuf> = (0..3).map(|i| root.join(format!("pfs_ost{i}"))).collect();
        let striped = StripedFs::from_dirs(dirs).unwrap();
        let pfs: Arc<dyn Vfs> = Arc::new(RateLimitedFs::new(striped, 4e9, 4e9));
        let sea = mount_cfg(&root, pfs.clone(), rules, tmpfs_cap);
        (sea, root, pfs)
    }

    #[test]
    fn writes_go_to_fastest_device_and_read_back() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/derived/a.dat");
        sea.write(p, &vec![7u8; MIB as usize]).unwrap();
        assert!(sea.exists(p));
        assert_eq!(sea.size(p).unwrap(), MIB);
        assert_eq!(sea.device_of("derived/a.dat").unwrap(), root.join("tmpfs").to_string_lossy());
        let data = sea.read(p).unwrap();
        assert!(data.iter().all(|&b| b == 7));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn overflow_spills_to_next_tier_then_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 4 * MIB);
        // floor = p*F = 2 MiB; tmpfs 4 MiB holds 2-3 files of 1 MiB
        let mut devices = Vec::new();
        for i in 0..250 {
            let p = PathBuf::from(format!("/sea/d/f{i:03}.dat"));
            sea.write(&p, &vec![1u8; MIB as usize]).unwrap();
            devices.push(sea.device_of(&format!("d/f{i:03}.dat")));
        }
        let on_tmpfs = devices.iter().flatten().filter(|d| d.contains("tmpfs")).count();
        let on_disk = devices.iter().flatten().filter(|d| d.contains("disk")).count();
        let on_pfs = devices.iter().filter(|d| d.is_none()).count();
        assert!(on_tmpfs >= 2 && on_tmpfs <= 3, "tmpfs {on_tmpfs}");
        assert!(on_disk >= 190, "disk {on_disk}");
        assert!(on_pfs >= 40, "pfs {on_pfs}");
        // the pfs fallback files really are on the pfs
        assert!(pfs.exists(Path::new("d/f249.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn move_mode_flushes_then_evicts() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**_final.dat", "**_final.dat", ""), 10 * MIB);
        let p = Path::new("/sea/out/b_final.dat");
        sea.write(p, &vec![3u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        // after the move: gone locally, present on PFS, still readable
        assert!(sea.device_of("out/b_final.dat").is_none());
        assert!(pfs.exists(Path::new("out/b_final.dat")));
        assert_eq!(sea.read(p).unwrap().len(), MIB as usize);
        assert_eq!(sea.mgmt_counters(), (1, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Acceptance: the flight recorder captures one full flush and one
    /// spill lifecycle as Chrome `ph:"X"` spans.
    #[test]
    fn flight_recorder_captures_flush_and_spill_lifecycles() {
        use crate::obs::trace;
        let _gate = crate::obs::test_gate();
        trace::set_enabled(true);
        // flush: a move-mode file drained by sync_mgmt
        let (sea, root, _) =
            mount(RuleSet::from_texts("**_final.dat", "**_final.dat", ""), 10 * MIB);
        sea.write(Path::new("/sea/out/t_final.dat"), &vec![3u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        // spill: a single small device with cold residents; a streaming
        // writer overruns it, so something must move down to the PFS
        // (self-spill or victim-spill — both record a "spill" span)
        let root2 = scratch("seafs_trace_spill");
        let pfs2 = Arc::new(RealFs::new(root2.join("pfs")).unwrap());
        let sea2 = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root2.join("dev0"), 0, 4 * MIB).unwrap()],
            pfs: pfs2,
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(),
            seed: 3,
            tuning: SeaTuning::default(),
        })
        .unwrap();
        for i in 0..2u8 {
            sea2.write(Path::new(&format!("/sea/cold{i}.dat")), &vec![i; MIB as usize])
                .unwrap();
        }
        {
            let mut f = sea2.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let chunk = vec![9u8; (256 * KIB) as usize];
            for k in 0..16u64 {
                f.pwrite_all(&chunk, k * 256 * KIB).unwrap();
            }
        }
        sea2.sync_mgmt().unwrap();
        trace::set_enabled(false);
        let json = trace::to_chrome_json();
        assert!(
            json.contains("\"name\":\"flush\",\"cat\":\"mgmt\",\"ph\":\"X\""),
            "flush lifecycle missing from trace"
        );
        assert!(
            json.contains("\"name\":\"spill\",\"cat\":\"mgmt\",\"ph\":\"X\""),
            "spill lifecycle missing from trace"
        );
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(&root2);
    }

    #[test]
    fn copy_mode_keeps_local_copy() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/x.dat");
        sea.write(p, &vec![5u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(sea.device_of("x.dat").is_some(), "local copy kept");
        assert!(pfs.exists(Path::new("x.dat")), "pfs copy exists");
        assert_eq!(sea.mgmt_counters(), (1, 0));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_mode_discards_without_persisting() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("", "*.log", ""), 10 * MIB);
        let p = Path::new("/sea/noise.log");
        sea.write(p, b"scratch").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(!sea.exists(p));
        assert!(!pfs.exists(Path::new("noise.log")));
        assert_eq!(sea.mgmt_counters(), (0, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn eviction_frees_space_for_later_files() {
        // Move everything: space should keep being recycled, so many more
        // files than tmpfs capacity all land on tmpfs eventually
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 4 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/s/f{i}.dat"));
            sea.write(&p, &vec![0u8; MIB as usize]).unwrap();
            sea.sync_mgmt().unwrap(); // drain so space is recycled
        }
        let (fl, ev) = sea.mgmt_counters();
        assert_eq!(fl, 20);
        assert_eq!(ev, 20);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn outside_mount_passes_through_to_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        sea.write(Path::new("plain/file.txt"), b"direct").unwrap();
        assert!(pfs.exists(Path::new("plain/file.txt")));
        assert_eq!(sea.read(Path::new("plain/file.txt")).unwrap(), b"direct");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_and_rename_within_mount() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let a = Path::new("/sea/a.dat");
        let b = Path::new("/sea/b.dat");
        sea.write(a, b"x").unwrap();
        sea.rename(a, b).unwrap();
        assert!(!sea.exists(a));
        assert_eq!(sea.read(b).unwrap(), b"x");
        sea.unlink(b).unwrap();
        assert!(!sea.exists(b));
        assert!(matches!(sea.unlink(b), Err(Error::NotFound(_))));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readdir_merges_local_and_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        pfs.write(Path::new("d/pfs_file"), b"1").unwrap();
        sea.write(Path::new("/sea/d/local_file"), b"2").unwrap();
        let names = sea.readdir(Path::new("/sea/d")).unwrap();
        assert_eq!(names, vec!["local_file", "pfs_file"]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn prefetch_pulls_matching_inputs() {
        let (sea, root, pfs) = mount(
            RuleSet::from_texts("", "", "inputs/*.dat"),
            10 * MIB,
        );
        pfs.write(Path::new("inputs/a.dat"), &vec![1u8; MIB as usize]).unwrap();
        pfs.write(Path::new("inputs/skip.txt"), b"no").unwrap();
        let n = sea.prefetch_dir("inputs").unwrap();
        assert_eq!(n, 1);
        assert!(sea.device_of("inputs/a.dat").is_some());
        assert!(sea.device_of("inputs/skip.txt").is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- handle-based API ---------------------------------------------------

    #[test]
    fn handle_streaming_write_places_and_reads_back() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/h/streamed.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            for k in 0..4u64 {
                f.pwrite_all(&vec![k as u8; 1024], k * 1024).unwrap();
            }
            assert_eq!(f.len().unwrap(), 4096);
        }
        assert!(sea.device_of("h/streamed.dat").is_some(), "placed locally");
        assert_eq!(sea.size(p).unwrap(), 4096);
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 4096);
        assert!(data[..1024].iter().all(|&b| b == 0));
        assert!(data[3072..].iter().all(|&b| b == 3));
        // partial read through a handle
        let mut f = sea.open(p, OpenMode::Read).unwrap();
        let mut mid = [0u8; 8];
        f.pread_exact(&mut mid, 2048).unwrap();
        assert!(mid.iter().all(|&b| b == 2));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn streaming_write_defers_mgmt_until_last_close() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let p = Path::new("/sea/defer.dat");
        let mut f = sea.open(p, OpenMode::Write).unwrap();
        f.pwrite_all(&vec![9u8; 4096], 0).unwrap();
        // handle still open: nothing enqueued, nothing flushed
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (0, 0));
        assert!(!pfs.exists(Path::new("defer.dat")));
        assert!(sea.device_of("defer.dat").is_some());
        drop(f);
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1), "move ran at close");
        assert!(pfs.exists(Path::new("defer.dat")));
        assert!(sea.device_of("defer.dat").is_none());
        assert_eq!(sea.read(p).unwrap(), vec![9u8; 4096]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn handle_space_accounting_credits_on_unlink() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let p = Path::new("/sea/acc.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(&vec![1u8; MIB as usize], 0).unwrap();
            f.set_len(MIB / 2).unwrap(); // shrink credits the ledger
        }
        assert_eq!(sea.size(p).unwrap(), MIB / 2);
        assert_eq!(sea.shared.accountant.total_free(), before - MIB / 2);
        sea.unlink(p).unwrap();
        assert_eq!(sea.shared.accountant.total_free(), before);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_moves_flushed_pfs_copy_too() {
        // regression: a Copy-mode flush used to leave the PFS replica
        // under the *old* name after a rename
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let a = Path::new("/sea/out/a.dat");
        let b = Path::new("/sea/out/b.dat");
        sea.write(a, b"payload").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(pfs.exists(Path::new("out/a.dat")), "flushed before rename");
        sea.rename(a, b).unwrap();
        assert!(!pfs.exists(Path::new("out/a.dat")), "old PFS name gone");
        assert!(pfs.exists(Path::new("out/b.dat")), "PFS copy follows rename");
        assert!(sea.device_of("out/b.dat").is_some());
        assert!(sea.device_of("out/a.dat").is_none());
        assert_eq!(sea.read(b).unwrap(), b"payload");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_of_unflushed_file_keeps_pending_mgmt() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        // write+rename before draining: the flush must follow the new name
        sea.write(Path::new("/sea/tmp.dat"), b"bytes").unwrap();
        sea.rename(Path::new("/sea/tmp.dat"), Path::new("/sea/kept.dat")).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(pfs.exists(Path::new("kept.dat")), "flushed under new name");
        assert!(!pfs.exists(Path::new("tmp.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn overwrite_supersedes_pending_flush() {
        // regression for the write-vs-flush race: the daemon must never
        // persist a half-overwritten entry; the final PFS bytes are the
        // final write's bytes
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/race.dat");
        for round in 0..10u8 {
            sea.write(p, &vec![round; 64 * 1024]).unwrap();
            sea.write(p, &vec![round ^ 0xFF; 64 * 1024]).unwrap();
            sea.sync_mgmt().unwrap();
            let got = pfs.read(Path::new("race.dat")).unwrap();
            assert_eq!(got, vec![round ^ 0xFF; 64 * 1024], "round {round}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_handle_writers_flush_pool_drains() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let sea = Arc::new(sea);
        const THREADS: usize = 8;
        const FILES: usize = 8;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let sea = sea.clone();
                scope.spawn(move || {
                    for i in 0..FILES {
                        let p = PathBuf::from(format!("/sea/w{t}/f{i}.dat"));
                        let mut f = sea.open(&p, OpenMode::Write).unwrap();
                        for k in 0..4u64 {
                            f.pwrite_all(&vec![(t * FILES + i) as u8; 4096], k * 4096)
                                .unwrap();
                        }
                    }
                });
            }
        });
        sea.sync_mgmt().unwrap();
        let (fl, ev) = sea.mgmt_counters();
        assert_eq!(fl, (THREADS * FILES) as u64);
        assert_eq!(ev, (THREADS * FILES) as u64);
        for t in 0..THREADS {
            for i in 0..FILES {
                let rel = format!("w{t}/f{i}.dat");
                assert!(sea.device_of(&rel).is_none(), "{rel} evicted");
                let got = pfs.read(Path::new(&rel)).unwrap();
                assert_eq!(got, vec![(t * FILES + i) as u8; 4 * 4096], "{rel}");
            }
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_open_read_during_flush_and_evict() {
        // readers racing the flush pool must always see either the local
        // or the PFS copy, never an error
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let sea = Arc::new(sea);
        let p = Path::new("/sea/hot.dat");
        sea.write(p, &vec![4u8; 32 * 1024]).unwrap();
        std::thread::scope(|scope| {
            let reader = {
                let sea = sea.clone();
                scope.spawn(move || {
                    for _ in 0..200 {
                        let data = sea.read(Path::new("/sea/hot.dat")).unwrap();
                        assert_eq!(data.len(), 32 * 1024);
                        assert!(data.iter().all(|&b| b == 4));
                    }
                })
            };
            let _ = reader;
        });
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.read(p).unwrap(), vec![4u8; 32 * 1024]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readwrite_handle_updates_in_place() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/upd.dat");
        sea.write(p, b"aaaaaaaa").unwrap();
        sea.sync_mgmt().unwrap();
        assert_eq!(pfs.read(Path::new("upd.dat")).unwrap(), b"aaaaaaaa");
        {
            let mut f = sea.open(p, OpenMode::ReadWrite).unwrap();
            f.pwrite_all(b"BB", 3).unwrap();
        }
        sea.sync_mgmt().unwrap();
        // re-opened for write => re-flushed with the patched bytes
        assert_eq!(sea.read(p).unwrap(), b"aaaBBaaa");
        assert_eq!(pfs.read(Path::new("upd.dat")).unwrap(), b"aaaBBaaa");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_writers_share_entry_and_mgmt_runs_once() {
        // regression: a ReadWrite open bumps the shared entry's
        // generation; the first handle's close must still decrement the
        // writer count or management never fires
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let p = Path::new("/sea/two.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(b"aaaa", 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        b.pwrite_all(b"bb", 4).unwrap();
        drop(a); // not the last writer: nothing enqueued yet
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (0, 0));
        drop(b); // last close fires the move
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1));
        assert_eq!(pfs.read(Path::new("two.dat")).unwrap(), b"aaaabb");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stale_writer_does_not_corrupt_superseding_placement() {
        // regression: a handle orphaned by an overwrite (drop_local
        // replaced its entry) must not inflate the new entry's size or
        // the device ledger
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let p = Path::new("/sea/stale.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![1u8; 1024], 0).unwrap();
        // supersede the placement while the old handle is still open
        sea.write(p, b"fresh").unwrap();
        // the stale handle writes to its orphaned inode, nothing else
        a.pwrite_all(&vec![2u8; 4096], 0).unwrap();
        assert_eq!(sea.size(p).unwrap(), 5);
        drop(a); // must not enqueue mgmt for the superseded entry
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 0), "one flush, for the overwrite");
        assert_eq!(sea.read(p).unwrap(), b"fresh");
        assert_eq!(pfs.read(Path::new("stale.dat")).unwrap(), b"fresh");
        assert_eq!(sea.shared.accountant.total_free(), before - 5);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_with_open_writer_is_refused() {
        // an open writer handle keys its registry updates by path, so a
        // rename under it is refused rather than stranding its count
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let a = Path::new("/sea/busy.dat");
        let b = Path::new("/sea/moved.dat");
        let mut f = sea.open(a, OpenMode::Write).unwrap();
        f.pwrite_all(b"x", 0).unwrap();
        assert!(matches!(sea.rename(a, b), Err(Error::InvalidArg(_))));
        drop(f);
        sea.rename(a, b).unwrap();
        assert!(sea.exists(b) && !sea.exists(a));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_over_existing_destination_reclaims_its_space() {
        // regression: replacing a destination entry must credit its
        // bytes back to the ledger and drop its local copy
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let a = Path::new("/sea/src.dat");
        let b = Path::new("/sea/dst.dat");
        sea.write(b, &vec![1u8; MIB as usize]).unwrap();
        sea.write(a, b"new").unwrap();
        sea.rename(a, b).unwrap();
        assert_eq!(sea.read(b).unwrap(), b"new");
        assert!(!sea.exists(a));
        assert_eq!(sea.shared.accountant.total_free(), before - 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_racing_flush_leaves_no_pfs_copy() {
        // regression: unlink must serialise with in-flight flush jobs or
        // a completing flush resurrects the deleted file on the PFS
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/u{i}.dat"));
            sea.write(&p, &vec![9u8; 32 * 1024]).unwrap(); // enqueues a move
            sea.unlink(&p).unwrap(); // races the flush pool
            sea.sync_mgmt().unwrap();
            assert!(!sea.exists(&p), "u{i} resurrected locally");
            assert!(!pfs.exists(Path::new(&format!("u{i}.dat"))), "u{i} on pfs");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- striped PFS backend stack ------------------------------------------

    #[test]
    fn striped_pfs_overwrite_and_rename_races() {
        // the same write-vs-flush and rename scenarios, with the PFS a
        // rate-limited striped backend (acceptance for the backend stack)
        let (sea, root, pfs) = mount_striped(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/race.dat");
        for round in 0..6u8 {
            sea.write(p, &vec![round; 64 * 1024]).unwrap();
            sea.write(p, &vec![round ^ 0xFF; 64 * 1024]).unwrap();
            sea.sync_mgmt().unwrap();
            let got = pfs.read(Path::new("race.dat")).unwrap();
            assert_eq!(got, vec![round ^ 0xFF; 64 * 1024], "round {round}");
        }
        // rename moves the flushed PFS copy, possibly across members
        let a = Path::new("/sea/out/a.dat");
        let b = Path::new("/sea/out/b.dat");
        sea.write(a, b"payload").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(pfs.exists(Path::new("out/a.dat")));
        sea.rename(a, b).unwrap();
        assert!(!pfs.exists(Path::new("out/a.dat")), "old PFS name gone");
        assert!(pfs.exists(Path::new("out/b.dat")), "PFS copy follows rename");
        assert_eq!(sea.read(b).unwrap(), b"payload");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn striped_pfs_unlink_racing_flush() {
        let (sea, root, pfs) = mount_striped(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/u{i}.dat"));
            sea.write(&p, &vec![9u8; 32 * 1024]).unwrap();
            sea.unlink(&p).unwrap();
            sea.sync_mgmt().unwrap();
            assert!(!sea.exists(&p), "u{i} resurrected locally");
            assert!(!pfs.exists(Path::new(&format!("u{i}.dat"))), "u{i} on pfs");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn striped_pfs_flush_pool_respects_member_gate() {
        // 8 workers, 2 members, 1 slot each: everything drains, and the
        // observed in-flight peak never exceeds the per-member cap
        let root = scratch("seafs_gate");
        let dirs: Vec<PathBuf> = (0..2).map(|i| root.join(format!("ost{i}"))).collect();
        let pfs: Arc<dyn Vfs> = Arc::new(StripedFs::from_dirs(dirs).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 100 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 2,
            rules: RuleSet::from_texts("**", "**", ""),
            seed: 3,
            tuning: SeaTuning {
                flush_workers: 8,
                registry_shards: 8,
                per_member_concurrency: 1,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        for i in 0..32 {
            let p = PathBuf::from(format!("/sea/g/f{i:02}.dat"));
            let mut f = sea.open(&p, OpenMode::Write).unwrap();
            f.pwrite_all(&vec![i as u8; 16 * 1024], 0).unwrap();
        }
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (32, 32));
        for i in 0..32 {
            let rel = format!("g/f{i:02}.dat");
            assert_eq!(pfs.read(Path::new(&rel)).unwrap(), vec![i as u8; 16 * 1024]);
        }
        let peaks = sea.flush_member_peaks().expect("gate active");
        assert_eq!(peaks.len(), 2);
        assert!(peaks.iter().all(|&pk| pk <= 1), "peaks {peaks:?}");
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- mid-stream spill ----------------------------------------------------

    fn tiny_device_mount(root: &Path, pfs: Arc<dyn Vfs>) -> SeaFs {
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs,
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("**", "**", ""),
            seed: 1,
            tuning: SeaTuning::default(),
        })
        .unwrap()
    }

    #[test]
    fn pwrite_past_device_capacity_spills_to_pfs() {
        // acceptance: a stream that outgrows its device completes via
        // spill instead of returning NoSpace
        let root = scratch("seafs_spill");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let before = sea.shared.accountant.total_free();
        let p = Path::new("/sea/grow.dat");
        let quarter = MIB as usize / 4;
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            // 4 MiB streamed in 256 KiB chunks outgrows the 2 MiB device
            for k in 0..16u64 {
                f.pwrite_all(&vec![k as u8; quarter], k * quarter as u64).unwrap();
            }
            // the handle keeps working after the migration
            let mut probe = [0u8; 4];
            f.pread_exact(&mut probe, 15 * quarter as u64).unwrap();
            assert_eq!(probe, [15u8; 4]);
            assert_eq!(f.len().unwrap(), 4 * MIB);
        }
        sea.sync_mgmt().unwrap();
        // migrated: off-device, on the PFS, ledger fully credited
        assert!(sea.device_of("grow.dat").is_none());
        assert!(pfs.exists(Path::new("grow.dat")));
        assert_eq!(
            sea.shared.accountant.total_free(),
            before,
            "spill credits the device ledger"
        );
        // byte-exact content through the mount
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 4 * MIB as usize);
        for (k, chunk) in data.chunks(quarter).enumerate() {
            assert!(chunk.iter().all(|&b| b == k as u8), "chunk {k}");
        }
        // no stranded writer count: the name unlinks and rewrites freely
        sea.unlink(p).unwrap();
        assert!(!sea.exists(p));
        sea.write(p, b"fresh").unwrap();
        assert_eq!(sea.read(p).unwrap(), b"fresh");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sibling_writer_follows_spill_to_pfs() {
        // two handles share the entry; one spills, the other's next
        // write must land on the PFS copy, not the orphaned device inode
        let root = scratch("seafs_spill2");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/shared.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![1u8; MIB as usize], 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        // b outgrows the 2 MiB device: spill migrates a's bytes too
        b.pwrite_all(&vec![2u8; 2 * MIB as usize], MIB).unwrap();
        assert!(sea.device_of("shared.dat").is_none(), "spilled");
        // a's next write follows the relocation onto the PFS copy
        a.pwrite_all(&vec![3u8; 4], 0).unwrap();
        drop(a);
        drop(b);
        sea.sync_mgmt().unwrap();
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 3 * MIB as usize);
        assert_eq!(&data[..4], &[3u8; 4]);
        assert!(data[4..MIB as usize].iter().all(|&v| v == 1));
        assert!(data[MIB as usize..].iter().all(|&v| v == 2));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_mode_spill_does_not_persist() {
        // a Remove-mode scratch file that spills must not leak onto the
        // PFS once its last writer closes
        let root = scratch("seafs_spill_rm");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("", "**", ""), // remove everything
            seed: 1,
            tuning: SeaTuning::default(),
        })
        .unwrap();
        let p = Path::new("/sea/scratch.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(&vec![7u8; 3 * MIB as usize], 0).unwrap(); // spills
            assert!(pfs.exists(Path::new("scratch.dat")), "spilled to the PFS");
        }
        sea.sync_mgmt().unwrap();
        assert!(!pfs.exists(Path::new("scratch.dat")), "Remove mode: not persisted");
        assert!(!sea.exists(p));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn spill_over_striped_pfs_round_trips() {
        // spill targets the striped backend stack, not just a plain dir
        let root = scratch("seafs_spill3");
        let dirs: Vec<PathBuf> = (0..3).map(|i| root.join(format!("ost{i}"))).collect();
        let striped = StripedFs::from_dirs(dirs).unwrap();
        let pfs: Arc<dyn Vfs> = Arc::new(RateLimitedFs::new(striped, 4e9, 4e9));
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/big.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            for k in 0..12u64 {
                f.pwrite_all(&vec![k as u8; MIB as usize / 4], k * MIB / 4).unwrap();
            }
        }
        assert_eq!(sea.size(p).unwrap(), 3 * MIB);
        assert!(pfs.exists(Path::new("big.dat")));
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 3 * MIB as usize);
        assert!(data[..MIB as usize / 4].iter().all(|&v| v == 0));
        assert!(data[11 * MIB as usize / 4..].iter().all(|&v| v == 11));
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- append mode ---------------------------------------------------------

    #[test]
    fn append_handle_extends_and_ignores_offsets() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/log.txt");
        {
            let mut f = sea.open(p, OpenMode::Append).unwrap();
            f.pwrite_all(b"one;", 0).unwrap();
            f.pwrite_all(b"two;", 999).unwrap(); // offset ignored
        }
        {
            // re-opening appends after the existing bytes
            let mut f = sea.open(p, OpenMode::Append).unwrap();
            f.pwrite_all(b"three;", 0).unwrap();
        }
        assert_eq!(sea.read(p).unwrap(), b"one;two;three;");
        assert_eq!(sea.size(p).unwrap(), 14);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_appenders_never_interleave_records() {
        // the O_APPEND satellite: offsets resolved per request under the
        // registry shard lock => every record lands contiguously
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let sea = Arc::new(sea);
        const REC: usize = 64;
        const PER: usize = 50;
        const THREADS: usize = 8;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let sea = sea.clone();
                scope.spawn(move || {
                    let mut f = sea
                        .open(Path::new("/sea/applog.bin"), OpenMode::Append)
                        .unwrap();
                    for _ in 0..PER {
                        f.pwrite_all(&[t as u8 + 1; REC], 0).unwrap();
                    }
                });
            }
        });
        sea.sync_mgmt().unwrap();
        let data = sea.read(Path::new("/sea/applog.bin")).unwrap();
        assert_eq!(data.len(), REC * PER * THREADS, "no lost records");
        let mut counts = [0usize; THREADS + 1];
        for rec in data.chunks(REC) {
            assert!(
                rec.iter().all(|&v| v == rec[0]),
                "interleaved record near byte {}",
                rec[0]
            );
            counts[rec[0] as usize] += 1;
        }
        for (t, &c) in counts.iter().enumerate().skip(1) {
            assert_eq!(c, PER, "thread {t} records");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn append_to_pfs_resident_file_appends_there() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        pfs.write(Path::new("pre.log"), b"head;").unwrap();
        {
            let mut f = sea.open(Path::new("/sea/pre.log"), OpenMode::Append).unwrap();
            f.pwrite_all(b"tail;", 0).unwrap();
        }
        assert_eq!(pfs.read(Path::new("pre.log")).unwrap(), b"head;tail;");
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- ledger diagnostics --------------------------------------------------

    #[test]
    fn ledger_reports_per_device_traffic() {
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        sea.write(Path::new("/sea/l.dat"), &vec![0u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap(); // move: flushed then evicted
        let ledger = sea.ledger();
        assert_eq!(ledger.len(), 3);
        let tmpfs = &ledger[0];
        assert_eq!(tmpfs.tier, 0);
        assert!(tmpfs.name.contains("tmpfs"));
        assert_eq!(tmpfs.capacity, 10 * MIB);
        assert_eq!(tmpfs.debits, MIB, "placement debited");
        assert_eq!(tmpfs.credits, MIB, "eviction credited");
        assert_eq!(tmpfs.used, 0);
        assert_eq!(tmpfs.free, 10 * MIB);
        // disks untouched
        assert_eq!(ledger[1].debits, 0);
        assert_eq!(ledger[2].debits, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- placement engines ---------------------------------------------------

    #[test]
    fn temperature_engine_spills_cold_victim_and_promotes_back() {
        // acceptance: under pressure the TemperatureEngine persists and
        // drops the coldest *resident* file — the active writer stays on
        // its device — and promotes it back once space frees
        let root = scratch("seafs_temp");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(), // Keep everything
            seed: 1,
            tuning: SeaTuning { engine: EngineKind::Temperature, ..SeaTuning::default() },
        })
        .unwrap();
        assert_eq!(sea.engine_name(), "temperature");
        // a cold resident file fills half the device
        sea.write(Path::new("/sea/cold.dat"), &vec![7u8; MIB as usize]).unwrap();
        assert!(sea.device_of("cold.dat").is_some());
        // a hot writer outgrows the remaining space
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..8u64 {
                f.pwrite_all(&vec![k as u8; quarter], k * quarter as u64).unwrap();
            }
            assert!(sea.device_of("hot.dat").is_some(), "active writer stays on-device");
            assert!(sea.device_of("cold.dat").is_none(), "cold resident spilled");
            assert!(pfs.exists(Path::new("cold.dat")), "victim persisted to the PFS");
        }
        sea.sync_mgmt().unwrap();
        let c = sea.counters();
        assert_eq!(c.victim_spills, 1, "one victim spill");
        assert_eq!(c.self_spills, 0, "the writer never migrated");
        // the victim reads back through the mount (from the PFS) —
        // which also re-heats it, making it a promotion candidate
        assert_eq!(sea.read(Path::new("/sea/cold.dat")).unwrap(), vec![7u8; MIB as usize]);
        // free the device: the hot spilled file is promoted back
        sea.unlink(Path::new("/sea/hot.dat")).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(sea.device_of("cold.dat").is_some(), "promoted back to a fast tier");
        assert_eq!(sea.counters().promotions, 1);
        assert_eq!(
            sea.counters().promote_bytes,
            MIB,
            "promotion traffic streamed through the mover"
        );
        assert_eq!(sea.read(Path::new("/sea/cold.dat")).unwrap(), vec![7u8; MIB as usize]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn paper_engine_reports_its_name_and_never_promotes() {
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        assert_eq!(sea.engine_name(), "paper");
        sea.write(Path::new("/sea/a.dat"), &vec![1u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap(); // move: flush + evict frees space
        let c = sea.counters();
        assert_eq!((c.flushes, c.evictions), (1, 1));
        assert_eq!(c.promotions, 0, "paper engine never promotes");
        assert_eq!(c.victim_spills, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- spill hardening (write serials) -------------------------------------

    #[test]
    fn spill_preserves_racing_sibling_writes() {
        // regression: a sibling's positioned write landing between the
        // spill's bulk copy and the registry flip must be detected (the
        // entry's write serial) and its range re-copied before the flip
        let root = scratch("seafs_spill_race");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/race.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![0x11u8; MIB as usize], 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        const REC: usize = 4096;
        const STRIDE: u64 = 64 * 1024;
        std::thread::scope(|scope| {
            let spiller = scope.spawn(move || {
                // outgrow the 2 MiB device: triggers the mid-stream
                // spill while the sibling keeps writing
                a.pwrite_all(&vec![0xAAu8; 2 * MIB as usize], MIB).unwrap();
                drop(a);
            });
            // land records across the first MiB while the spill runs
            for k in 0..16u64 {
                b.pwrite_all(&vec![0xBBu8; REC], k * STRIDE).unwrap();
                std::thread::yield_now();
            }
            spiller.join().unwrap();
        });
        drop(b);
        sea.sync_mgmt().unwrap();
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 3 * MIB as usize);
        for k in 0..16u64 {
            let off = (k * STRIDE) as usize;
            assert!(
                data[off..off + REC].iter().all(|&v| v == 0xBB),
                "sibling record {k} lost across the spill"
            );
        }
        assert!(data[2 * MIB as usize..].iter().all(|&v| v == 0xAA));
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- streaming DataMover (bounded-memory transfers) ----------------------

    #[test]
    fn flush_streams_bytes_and_reports_gauges() {
        // a Move-mode flush streams through the DataMover: byte gauges
        // report the traffic and the copy buffers stay bounded by
        // chunk_bytes × copy_window
        let root = scratch("seafs_gauges");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("d0"), 0, 10 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("**", "**", ""),
            seed: 1,
            tuning: SeaTuning {
                chunk_bytes: (64 * KIB) as usize,
                copy_window: 2,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        sea.write(Path::new("/sea/g.dat"), &vec![5u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        let c = sea.counters();
        assert_eq!((c.flushes, c.evictions), (1, 1));
        assert_eq!(c.flush_bytes, MIB, "flush traffic observed");
        assert_eq!(c.spill_bytes, 0);
        assert!(c.peak_copy_buffer_bytes > 0, "buffer lease observed");
        assert!(
            c.peak_copy_buffer_bytes <= 2 * 64 * KIB,
            "peak {} exceeds chunk_bytes x copy_window",
            c.peak_copy_buffer_bytes
        );
        assert_eq!(pfs.read(Path::new("g.dat")).unwrap(), vec![5u8; MIB as usize]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn victim_spill_streams_with_bounded_buffers() {
        // ISSUE 4 regression: a victim spill of a file ≫ chunk_bytes
        // must not materialize it — peak copy-buffer bytes stay within
        // chunk_bytes × copy_window while the bytes land intact
        let root = scratch("seafs_victim_stream");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(), // Keep: residency managed by pressure
            seed: 1,
            tuning: SeaTuning {
                engine: EngineKind::Temperature,
                chunk_bytes: (16 * KIB) as usize,
                copy_window: 2,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        // the cold resident is 64x the chunk size
        sea.write(Path::new("/sea/cold.dat"), &vec![7u8; MIB as usize]).unwrap();
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..8u64 {
                f.pwrite_all(&vec![k as u8; quarter], k * quarter as u64).unwrap();
            }
        }
        sea.sync_mgmt().unwrap();
        let c = sea.counters();
        assert_eq!(c.victim_spills, 1, "cold resident spilled: {c:?}");
        assert_eq!(c.spill_bytes, MIB, "victim traffic counts as spill");
        assert!(
            c.peak_copy_buffer_bytes <= 2 * 16 * KIB,
            "peak {} exceeds chunk_bytes x copy_window",
            c.peak_copy_buffer_bytes
        );
        assert_eq!(pfs.read(Path::new("cold.dat")).unwrap(), vec![7u8; MIB as usize]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn flush_over_stripe_mode_pfs_fans_out_across_members() {
        // chunk-striped PFS: one large file's flush lands parts on
        // every member — single-file bandwidth aggregates across OSTs
        const STRIPE: u64 = 256 * KIB;
        let root = scratch("seafs_stripefan");
        let dirs: Vec<PathBuf> = (0..4).map(|i| root.join(format!("pfs_ost{i}"))).collect();
        let pfs: Arc<dyn Vfs> =
            Arc::new(StripedFs::from_dirs_striped(dirs, STRIPE).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("d0"), 0, 10 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("**", "**", ""), // move everything
            seed: 1,
            tuning: SeaTuning::default(),
        })
        .unwrap();
        let payload: Vec<u8> = (0..2 * MIB as usize).map(|k| (k / 1000) as u8).collect();
        {
            let mut f = sea.open(Path::new("/sea/fan.dat"), OpenMode::Write).unwrap();
            f.pwrite_all(&payload, 0).unwrap();
        }
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1));
        // 8 stripes over 4 members: every member holds exactly 2
        for i in 0..4 {
            let part = root.join(format!("pfs_ost{i}")).join("fan.dat");
            let plen = std::fs::metadata(&part).map(|m| m.len()).unwrap_or(0);
            assert_eq!(plen, 2 * STRIPE, "member {i} holds its share");
        }
        // the evicted file reads back byte-exact through the mount
        assert_eq!(sea.read(Path::new("/sea/fan.dat")).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_cancels_stale_promotion_of_dead_path() {
        // ISSUE 4 satellite: the engine must forget unlinked files —
        // a spilled-then-unlinked victim must not be promoted back
        let root = scratch("seafs_forget");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(),
            seed: 1,
            tuning: SeaTuning { engine: EngineKind::Temperature, ..SeaTuning::default() },
        })
        .unwrap();
        sea.write(Path::new("/sea/cold.dat"), &vec![7u8; MIB as usize]).unwrap();
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            f.pwrite_all(&vec![9u8; 2 * MIB as usize], 0).unwrap();
        }
        assert!(sea.device_of("cold.dat").is_none(), "victim spilled");
        // re-heat the victim (promotion candidate), then kill the path
        let _ = sea.read(Path::new("/sea/cold.dat")).unwrap();
        sea.unlink(Path::new("/sea/cold.dat")).unwrap();
        // freeing the device would promote the victim — but it is gone
        sea.unlink(Path::new("/sea/hot.dat")).unwrap();
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.counters().promotions, 0, "dead path never promotes");
        assert!(!sea.exists(Path::new("/sea/cold.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- mapped views (PageCache layer) --------------------------------------

    #[test]
    fn dirty_mapped_view_survives_mid_stream_spill() {
        // ISSUE 5 regression: a dirty MappedView racing a mid-stream
        // spill must land its write-back on the post-spill PFS replica,
        // never resurrect (or write to) the orphaned device inode
        use crate::vfs::pages::{MapMode, PageCache};
        let root = scratch("seafs_map_spill");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/mapped.dat");
        let cache: Arc<PageCache> = sea.page_cache();
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![0x11u8; MIB as usize], 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        {
            let mut view = a.map(&cache, 0, MIB, MapMode::Write).unwrap();
            // dirty a page: the bytes exist only in the cache
            view.write_at(&[0xDDu8; 4096], 0).unwrap();
            // the sibling outgrows the 2 MiB device: the entry spills
            // mid-stream and the device copy is unlinked
            b.pwrite_all(&vec![0xAAu8; 2 * MIB as usize], MIB).unwrap();
            assert!(sea.device_of("mapped.dat").is_none(), "spilled");
            // write-back follows the relocation onto the PFS replica
            view.msync().unwrap();
        }
        drop(a);
        drop(b);
        sea.sync_mgmt().unwrap();
        let on_pfs = pfs.read(Path::new("mapped.dat")).unwrap();
        assert_eq!(on_pfs.len(), 3 * MIB as usize);
        assert!(
            on_pfs[..4096].iter().all(|&v| v == 0xDD),
            "dirty page written back to the post-spill replica"
        );
        assert!(on_pfs[4096..MIB as usize].iter().all(|&v| v == 0x11));
        assert!(on_pfs[MIB as usize..].iter().all(|&v| v == 0xAA));
        // the device holds nothing: nothing was resurrected there
        assert!(
            std::fs::read_dir(root.join("tiny"))
                .map(|d| d.count() == 0)
                .unwrap_or(true),
            "device copy gone after the spill"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mapped_view_refaults_after_spill_instead_of_serving_stale_bytes() {
        // generation check: pages cached before a spill are invalidated
        // by the registry generation bump, so post-spill sibling writes
        // are visible through the view instead of stale device bytes
        use crate::vfs::pages::{MapMode, PageCache};
        let root = scratch("seafs_map_gen");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/gen.dat");
        let cache: Arc<PageCache> = sea.page_cache();
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![0x11u8; MIB as usize], 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        {
            let mut view = a.map(&cache, 0, MIB, MapMode::Read).unwrap();
            let mut buf = [0u8; 4096];
            view.read_at(&mut buf, 0).unwrap();
            assert!(buf.iter().all(|&v| v == 0x11), "pre-spill bytes cached");
            // spill, then a sibling write that only exists on the PFS
            b.pwrite_all(&vec![0xAAu8; 2 * MIB as usize], MIB).unwrap();
            assert!(sea.device_of("gen.dat").is_none(), "spilled");
            b.pwrite_all(&[0x99u8; 4096], 0).unwrap();
            // the view re-faults through the relocated handle
            view.read_at(&mut buf, 0).unwrap();
            assert!(
                buf.iter().all(|&v| v == 0x99),
                "stale cached device bytes served after the spill"
            );
        }
        drop(a);
        drop(b);
        sea.sync_mgmt().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mapped_faults_heat_files_for_the_temperature_engine() {
        // ISSUE 5: page faults feed PlacementEngine::on_access — a
        // mapped-read file outheats an equally-opened sibling, so the
        // sibling is the spill victim under pressure
        use crate::vfs::pages::{MapMode, PageCache};
        let root = scratch("seafs_map_heat");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev"), 0, 4 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(), // Keep: residency managed by pressure
            seed: 1,
            tuning: SeaTuning { engine: EngineKind::Temperature, ..SeaTuning::default() },
        })
        .unwrap();
        let cache: Arc<PageCache> = sea.page_cache();
        sea.write(Path::new("/sea/cold.dat"), &vec![1u8; MIB as usize]).unwrap();
        sea.write(Path::new("/sea/warm.dat"), &vec![2u8; MIB as usize]).unwrap();
        // symmetric handle opens; only warm.dat is map-read (faults)
        {
            let mut c = sea.open(Path::new("/sea/cold.dat"), OpenMode::ReadWrite).unwrap();
            let mut w = sea.open(Path::new("/sea/warm.dat"), OpenMode::ReadWrite).unwrap();
            {
                let mut view = w.map(&cache, 0, MIB, MapMode::Read).unwrap();
                let mut buf = vec![0u8; 64 * KIB as usize];
                for k in 0..8u64 {
                    view.read_at(&mut buf, k * 128 * KIB).unwrap();
                }
            }
            assert!(sea.counters().page_faults > 0, "mapped reads faulted");
            drop(c);
            drop(w);
        }
        // a hot writer outgrows the device: the engine must pick the
        // un-mapped (colder) file as the victim
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..10u64 {
                f.pwrite_all(&vec![9u8; quarter], k * quarter as u64).unwrap();
            }
        }
        assert!(sea.device_of("cold.dat").is_none(), "un-mapped file spilled");
        assert!(
            sea.device_of("warm.dat").is_some(),
            "map-heated file stayed resident"
        );
        sea.sync_mgmt().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn spill_invalidates_frames_of_every_view() {
        // ISSUE 6: frames are keyed (identity, generation, page) and
        // shared across views — a reader view hits the writer view's
        // frames without re-faulting, and a mid-stream spill's
        // generation bump orphans *both* views' frames at once:
        // neither resurrects device bytes
        use crate::vfs::pages::{MapMode, PageCache};
        let root = scratch("seafs_map_spill_all");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = tiny_device_mount(&root, pfs.clone());
        let p = Path::new("/sea/all.dat");
        let cache: Arc<PageCache> = sea.page_cache();
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![0x11u8; MIB as usize], 0).unwrap();
        let mut r = sea.open(p, OpenMode::Read).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        {
            let mut va = a.map(&cache, 0, MIB, MapMode::Read).unwrap();
            let mut vr = r.map(&cache, 0, MIB, MapMode::Read).unwrap();
            let mut buf = [0u8; 4096];
            va.read_at(&mut buf, 0).unwrap();
            assert!(buf.iter().all(|&v| v == 0x11));
            let pre = sea.counters();
            vr.read_at(&mut buf, 0).unwrap();
            assert!(buf.iter().all(|&v| v == 0x11));
            let post = sea.counters();
            assert_eq!(
                post.page_faults, pre.page_faults,
                "the reader view hit the writer view's frame"
            );
            assert!(
                post.page_shared_hits > pre.page_shared_hits,
                "cross-view hit counted"
            );
            // the sibling outgrows the 2 MiB device: the entry spills
            // mid-stream and only the PFS replica carries this write
            b.pwrite_all(&vec![0xAAu8; 2 * MIB as usize], MIB).unwrap();
            assert!(sea.device_of("all.dat").is_none(), "spilled");
            b.pwrite_all(&[0x99u8; 4096], 0).unwrap();
            // both views re-fault through their relocated handles; the
            // first re-fault installs one fresh frame the sibling hits
            let before = sea.counters();
            va.read_at(&mut buf, 0).unwrap();
            assert!(
                buf.iter().all(|&v| v == 0x99),
                "writer view served stale device bytes after the spill"
            );
            vr.read_at(&mut buf, 0).unwrap();
            assert!(
                buf.iter().all(|&v| v == 0x99),
                "reader view served stale device bytes after the spill"
            );
            let after = sea.counters();
            assert_eq!(
                after.page_faults,
                before.page_faults + 1,
                "one re-fault covers both views"
            );
        }
        drop(a);
        drop(r);
        drop(b);
        sea.sync_mgmt().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cold_writer_loses_victim_election_to_a_hot_reader() {
        // ISSUE 6 satellite: read-only handles heat the engine on
        // pread — a file that is only ever *read* outheats its cold
        // sibling, which then loses the victim election under pressure
        let root = scratch("seafs_reader_heat");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev"), 0, 4 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(), // Keep: residency managed by pressure
            seed: 1,
            tuning: SeaTuning { engine: EngineKind::Temperature, ..SeaTuning::default() },
        })
        .unwrap();
        sea.write(Path::new("/sea/cold.dat"), &vec![1u8; MIB as usize]).unwrap();
        sea.write(Path::new("/sea/warm.dat"), &vec![2u8; MIB as usize]).unwrap();
        // heat warm.dat through a plain read-only handle — no mapped
        // views involved, preads alone must feed on_access
        {
            let mut r = sea.open(Path::new("/sea/warm.dat"), OpenMode::Read).unwrap();
            let mut buf = vec![0u8; 64 * KIB as usize];
            for k in 0..8u64 {
                r.pread_exact(&mut buf, k * 128 * KIB).unwrap();
            }
            assert!(
                matches!(r.pwrite(b"x", 0), Err(Error::InvalidArg(_))),
                "read-only sea handles refuse writes"
            );
        }
        // a hot writer outgrows the device: the engine must pick the
        // never-read (colder) file as the victim
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..10u64 {
                f.pwrite_all(&vec![9u8; quarter], k * quarter as u64).unwrap();
            }
        }
        assert!(sea.device_of("cold.dat").is_none(), "never-read file spilled");
        assert!(
            sea.device_of("warm.dat").is_some(),
            "read-heated file stayed resident"
        );
        sea.sync_mgmt().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn whole_file_read_counts_one_access() {
        // review regression: `SeaFs::read` streams in chunk_bytes-sized
        // preads through a *quiet* reader handle — one read() call must
        // heat the engine exactly once, not once per chunk, or a single
        // bulk read of a large file would outheat a deliberately
        // re-read sibling and steal its victim election
        let root = scratch("seafs_read_one_access");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev"), 0, 4 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(),
            seed: 1,
            tuning: SeaTuning {
                engine: EngineKind::Temperature,
                // near-1 decay: heat ≈ touch count, so the election
                // cleanly separates one access (quiet read) from the
                // 16-per-chunk accounting this test guards against
                heat_decay: 0.99,
                chunk_bytes: (64 * KIB) as usize,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        sea.write(Path::new("/sea/bulk.dat"), &vec![1u8; MIB as usize]).unwrap();
        sea.write(Path::new("/sea/warm.dat"), &vec![2u8; MIB as usize]).unwrap();
        // warm.dat: a handful of deliberate handle reads
        {
            let mut r = sea.open(Path::new("/sea/warm.dat"), OpenMode::Read).unwrap();
            let mut buf = vec![0u8; 64 * KIB as usize];
            for k in 0..4u64 {
                r.pread_exact(&mut buf, k * 128 * KIB).unwrap();
            }
        }
        // bulk.dat: ONE whole-file read, streamed as 16 chunks
        let got = sea.read(Path::new("/sea/bulk.dat")).unwrap();
        assert_eq!(got.len(), MIB as usize);
        assert!(got.iter().all(|&b| b == 1));
        // pressure: a hot writer outgrows the device; the victim must
        // be the single-access bulk file, not the re-read warm one
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..10u64 {
                f.pwrite_all(&vec![9u8; quarter], k * quarter as u64).unwrap();
            }
        }
        assert!(
            sea.device_of("bulk.dat").is_none(),
            "one whole-file read left bulk.dat coldest: it spilled"
        );
        assert!(
            sea.device_of("warm.dat").is_some(),
            "the re-read file out-heated a single bulk read and stayed"
        );
        sea.sync_mgmt().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- stripe-mode flush gate (PfsSlots fan-out) ---------------------------

    #[test]
    fn stripe_mode_flush_charges_every_touched_member() {
        // ISSUE 5 satellite (open PR 4 limit): a stripe-mode file's
        // flush fans out across members, so it must hold one slot per
        // member it touches — not a single hash-picked slot
        const STRIPE: u64 = 256 * KIB;
        let root = scratch("seafs_stripe_slots");
        let mk = |sub: &str| {
            let dirs: Vec<PathBuf> = (0..4)
                .map(|i| root.join(format!("{sub}_ost{i}")))
                .collect();
            let pfs: Arc<dyn Vfs> =
                Arc::new(StripedFs::from_dirs_striped(dirs, STRIPE).unwrap());
            SeaFs::mount(SeaFsConfig {
                mountpoint: PathBuf::from("/sea"),
                devices: vec![DeviceSpec::dir(root.join(format!("{sub}_dev")), 0, 64 * MIB)
                    .unwrap()],
                pfs,
                max_file_size: MIB,
                parallel_procs: 1,
                rules: RuleSet::from_texts("**", "**", ""), // move everything
                seed: 1,
                tuning: SeaTuning {
                    per_member_concurrency: 1,
                    ..SeaTuning::default()
                },
            })
            .unwrap()
        };
        // a 4-stripe file touches all 4 members: each is charged
        let sea = mk("full");
        sea.write(Path::new("/sea/wide.dat"), &vec![7u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1));
        assert_eq!(
            sea.flush_member_peaks().unwrap(),
            vec![1, 1, 1, 1],
            "the fan-out flush held a slot on every member"
        );
        // a sub-stripe file touches member 0 only
        let sea = mk("small");
        sea.write(Path::new("/sea/narrow.dat"), &vec![7u8; (STRIPE / 2) as usize])
            .unwrap();
        sea.sync_mgmt().unwrap();
        assert_eq!(
            sea.flush_member_peaks().unwrap(),
            vec![1, 0, 0, 0],
            "a one-stripe file charges only the member holding it"
        );
        // concurrency: many wide flushes through 8 workers never exceed
        // the per-member cap
        let sea = mk("many");
        for i in 0..8 {
            let p = PathBuf::from(format!("/sea/w{i}.dat"));
            sea.write(&p, &vec![i as u8; MIB as usize]).unwrap();
        }
        sea.sync_mgmt().unwrap();
        let peaks = sea.flush_member_peaks().unwrap();
        assert!(peaks.iter().all(|&pk| pk <= 1), "gate violated: {peaks:?}");
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- mount-time prefetch -------------------------------------------------

    #[test]
    fn mount_time_prefetch_pass_pulls_matching_inputs() {
        let root = scratch("seafs_prefetch_mount");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        pfs.write(Path::new("inputs/a.dat"), &vec![1u8; MIB as usize]).unwrap();
        pfs.write(Path::new("inputs/deep/b.dat"), &vec![2u8; 1024]).unwrap();
        pfs.write(Path::new("inputs/skip.txt"), b"no").unwrap();
        let sea = mount_cfg(
            &root,
            pfs.clone(),
            RuleSet::from_texts("", "", "inputs/**.dat"),
            10 * MIB,
        );
        assert_eq!(sea.counters().prefetched, 2, "both .dat files pulled in");
        assert_eq!(
            sea.counters().prefetch_bytes,
            MIB + 1024,
            "prefetch traffic streamed through the mover"
        );
        assert!(sea.device_of("inputs/a.dat").is_some());
        assert!(sea.device_of("inputs/deep/b.dat").is_some());
        assert!(sea.device_of("inputs/skip.txt").is_none());
        // the prefetched copy serves reads locally, byte-exact
        assert_eq!(
            sea.read(Path::new("/sea/inputs/a.dat")).unwrap(),
            vec![1u8; MIB as usize]
        );
        // a later explicit pass is idempotent: already resident
        assert_eq!(sea.prefetch_dir("inputs").unwrap(), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- transparent cold-tier compression -----------------------------------

    /// Compressible payload whose bytes depend on position — constant
    /// data would mask frame-ordering and offset-mapping bugs.
    fn banded(len: usize) -> Vec<u8> {
        (0..len).map(|i| (i / 4096) as u8).collect()
    }

    #[test]
    fn compressed_flush_shrinks_replica_but_every_surface_stays_logical() {
        let root = scratch("seafs_compress_flush");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tmpfs"), 0, 10 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("**", "**", ""), // move everything
            seed: 1,
            tuning: SeaTuning {
                compress: true,
                chunk_bytes: 128 * KIB as usize, // multi-frame container
                ..SeaTuning::default()
            },
        })
        .unwrap();
        let data = banded(MIB as usize);
        let p = Path::new("/sea/out/cold.dat");
        sea.write(p, &data).unwrap();
        sea.sync_mgmt().unwrap(); // move: flush then evict
        assert!(sea.device_of("out/cold.dat").is_none(), "evicted");
        // the PFS replica is a framed container, physically smaller...
        let physical = pfs.size(Path::new("out/cold.dat")).unwrap();
        assert!(physical < MIB / 2, "compressible corpus shrank: {physical}");
        // ...while stat, read and readdir-side sizes stay logical
        assert_eq!(sea.size(p).unwrap(), MIB);
        assert_eq!(sea.read(p).unwrap(), data);
        // the gauges carry both columns: logical moved, physical stored
        let c = sea.counters();
        assert_eq!(c.flush_bytes, MIB);
        assert_eq!(c.flush_physical_bytes, physical);
        // a positioned read decodes exactly the frames it needs —
        // straddle a frame boundary on purpose
        let mut f = sea.open(p, OpenMode::Read).unwrap();
        assert_eq!(f.len().unwrap(), MIB);
        let off = 700 * KIB as usize;
        let mut got = vec![0u8; 64 * KIB as usize];
        let mut done = 0usize;
        while done < got.len() {
            let n = f.pread(&mut got[done..], (off + done) as u64).unwrap();
            assert!(n > 0, "pread stalled at {done}");
            done += n;
        }
        assert_eq!(got, data[off..off + got.len()]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn compressed_spill_stat_promote_round_trip_is_byte_identical() {
        // satellite: flush → stat → promote over a compressed replica.
        // The victim spill encodes, stat reports logical bytes while
        // spilled, and the promotion decodes back onto the fast tier.
        let root = scratch("seafs_compress_promote");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tiny"), 0, 2 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::default(), // Keep everything
            seed: 1,
            tuning: SeaTuning {
                engine: EngineKind::Temperature,
                compress: true,
                chunk_bytes: 128 * KIB as usize,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        let data = banded(MIB as usize);
        sea.write(Path::new("/sea/cold.dat"), &data).unwrap();
        // a hot writer outgrows the remaining space: the cold resident
        // is victim-spilled through the encoding mover
        {
            let mut f = sea.open(Path::new("/sea/hot.dat"), OpenMode::Write).unwrap();
            let quarter = MIB as usize / 4;
            for k in 0..8u64 {
                f.pwrite_all(&vec![k as u8; quarter], k * quarter as u64).unwrap();
            }
            assert!(sea.device_of("cold.dat").is_none(), "cold resident spilled");
        }
        sea.sync_mgmt().unwrap();
        let physical = pfs.size(Path::new("cold.dat")).unwrap();
        assert!(physical < MIB / 2, "spilled replica is compressed: {physical}");
        let c = sea.counters();
        assert_eq!(c.victim_spills, 1);
        assert!(
            c.spill_physical_bytes < c.spill_bytes,
            "spill moved fewer physical than logical bytes: {} vs {}",
            c.spill_physical_bytes,
            c.spill_bytes
        );
        // stat while spilled: logical, never the container length
        assert_eq!(sea.size(Path::new("/sea/cold.dat")).unwrap(), MIB);
        // reading re-heats the victim (decoding transparently) ...
        assert_eq!(sea.read(Path::new("/sea/cold.dat")).unwrap(), data);
        // ... and freeing the device promotes it back
        sea.unlink(Path::new("/sea/hot.dat")).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(sea.device_of("cold.dat").is_some(), "promoted back");
        let c = sea.counters();
        assert_eq!(c.promotions, 1);
        assert_eq!(c.promote_bytes, MIB, "promotion streams logical bytes");
        assert_eq!(
            c.promote_physical_bytes, physical,
            "promotion read the compressed container"
        );
        // the promoted device copy is raw logical bytes
        let dev_copy = std::fs::metadata(root.join("tiny").join("cold.dat")).unwrap();
        assert_eq!(dev_copy.len(), MIB);
        assert_eq!(sea.read(Path::new("/sea/cold.dat")).unwrap(), data);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn in_place_writers_rewrite_compressed_replicas_raw_first() {
        // ReadWrite / Append on an evicted (untracked) compressed
        // replica must not patch the framed container: the mount
        // rewrites it raw, then lets the writer at it.
        let root = scratch("seafs_compress_rw");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tmpfs"), 0, 10 * MIB).unwrap()],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 1,
            rules: RuleSet::from_texts("**", "**", ""), // move everything
            seed: 1,
            tuning: SeaTuning {
                compress: true,
                chunk_bytes: 128 * KIB as usize,
                ..SeaTuning::default()
            },
        })
        .unwrap();
        let mut data = banded(512 * KIB as usize);
        let p = Path::new("/sea/patch.dat");
        sea.write(p, &data).unwrap();
        sea.sync_mgmt().unwrap(); // move: the PFS copy is compressed
        assert!(pfs.size(Path::new("patch.dat")).unwrap() < data.len() as u64 / 2);
        {
            let mut f = sea.open(p, OpenMode::ReadWrite).unwrap();
            f.pwrite_all(b"PATCH", 300_000).unwrap();
        }
        data[300_000..300_005].copy_from_slice(b"PATCH");
        // the replica is plain bytes now, patched, and byte-identical
        assert_eq!(pfs.size(Path::new("patch.dat")).unwrap(), data.len() as u64);
        assert_eq!(sea.read(p).unwrap(), data);
        // an append extends at the logical end
        {
            let mut f = sea.open(p, OpenMode::Append).unwrap();
            f.pwrite_all(b"TAIL", 0).unwrap();
        }
        data.extend_from_slice(b"TAIL");
        assert_eq!(sea.read(p).unwrap(), data);
        let _ = std::fs::remove_dir_all(&root);
    }
}
