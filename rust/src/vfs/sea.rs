//! [`SeaFs`] — the paper's library, real-bytes flavour.
//!
//! A Sea mount wraps a *long-term* backend (the "PFS": any [`Vfs`],
//! typically rate-limited to emulate a loaded Lustre) plus an ordered set
//! of fast device directories (tmpfs `/dev/shm`, local disk dirs).
//! Every path under the logical mountpoint is translated to the fastest
//! eligible device (the same `hierarchy` selection the simulator uses);
//! paths outside the mountpoint pass through to the PFS untouched —
//! exactly the interception semantics of the paper's glibc wrappers.
//!
//! Placement happens at [`Vfs::open`]: a writer handle reserves a device
//! slot, debits space as the file grows, and only when the **last**
//! writer handle closes is the file handed to memory management. The
//! Table 1 modes (Copy → replicate to PFS; Move → replicate then drop
//! local; Remove → drop without persisting) are applied asynchronously by
//! a **flush pool** of worker threads (a multi-worker generalisation of
//! the paper's §5.1 daemon) so several files flush to the PFS in
//! parallel. File metadata lives in an N-way **sharded registry** (one
//! mutex per shard) so concurrent open/read/close traffic on different
//! files never serialises on a single global lock.
//!
//! Flush jobs carry the registry entry's *generation*: a racing
//! overwrite bumps the generation, so a stale job is discarded instead of
//! flushing half-overwritten bytes, and per-file flush serialisation
//! keeps two generations of the same file from interleaving on the PFS.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fs;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::hierarchy::{select_device, DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::placement::rules::{MgmtMode, RuleSet};
use crate::util::Rng;
use crate::vfs::real::RealFile;
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// Registry shards: enough to keep 2× typical worker counts from
/// colliding, small enough that readdir's full sweep stays cheap.
const REGISTRY_SHARDS: usize = 16;

/// Flush pool size (the paper used a single daemon; parallel flushing
/// overlaps several PFS transfers).
const FLUSH_WORKERS: usize = 4;

/// Configuration of a real Sea mount.
pub struct SeaFsConfig {
    /// Logical mountpoint prefix (e.g. `/sea`).
    pub mountpoint: PathBuf,
    /// Fast device directories: (directory, tier rank, capacity bytes).
    pub devices: Vec<(PathBuf, u8, u64)>,
    /// Long-term storage backend.
    pub pfs: Arc<dyn Vfs>,
    /// Max file size `F` declared by the user.
    pub max_file_size: u64,
    /// Parallel process count `p` declared by the user.
    pub parallel_procs: u64,
    /// Rule lists.
    pub rules: RuleSet,
    /// PRNG seed for same-tier shuffling.
    pub seed: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    dev: DeviceRef,
    size: u64,
    flushed: bool,
    /// Content version: bumped on every (re)placement or writer open;
    /// flush jobs carry the generation they were enqueued for and stand
    /// down when it no longer matches (a newer write superseded them).
    generation: u64,
    /// Entry identity: assigned when the entry is inserted and never
    /// changed in place. Handles record the epoch of the entry their
    /// writer count lives in, so a handle orphaned by `drop_local`
    /// (entry replaced) never touches the superseding entry, while
    /// concurrent in-place writers (who share one entry across
    /// generation bumps) still decrement correctly on close.
    epoch: u64,
    /// Open writer handles; management is deferred until this drops to 0.
    writers: u32,
}

/// One unit of deferred memory management.
struct Job {
    mode: MgmtMode,
    rel: String,
    gen: u64,
}

/// N-way sharded `rel -> Entry` map: per-shard mutexes instead of one
/// global lock.
struct Registry {
    shards: Vec<Mutex<HashMap<String, Entry>>>,
}

impl Registry {
    fn new(n: usize) -> Registry {
        Registry {
            shards: (0..n.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<HashMap<String, Entry>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn get(&self, key: &str) -> Option<Entry> {
        self.shard(key).lock().expect("registry poisoned").get(key).cloned()
    }

    fn contains(&self, key: &str) -> bool {
        self.shard(key).lock().expect("registry poisoned").contains_key(key)
    }

    fn insert(&self, key: String, e: Entry) {
        self.shard(&key).lock().expect("registry poisoned").insert(key, e);
    }

    fn remove(&self, key: &str) -> Option<Entry> {
        self.shard(key).lock().expect("registry poisoned").remove(key)
    }

    /// Remove `key` only when `pred` holds for its current entry.
    fn remove_if(&self, key: &str, pred: impl FnOnce(&Entry) -> bool) -> Option<Entry> {
        let mut m = self.shard(key).lock().expect("registry poisoned");
        let matches = match m.get(key) {
            Some(e) => pred(e),
            None => false,
        };
        if matches {
            m.remove(key)
        } else {
            None
        }
    }

    /// Mutate the entry for `key` under its shard lock, returning the
    /// closure's result (or `None` when absent).
    fn update<R>(&self, key: &str, f: impl FnOnce(&mut Entry) -> R) -> Option<R> {
        let mut m = self.shard(key).lock().expect("registry poisoned");
        m.get_mut(key).map(f)
    }

    /// Snapshot of every key across all shards.
    fn keys(&self) -> Vec<String> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().expect("registry poisoned").keys().cloned());
        }
        out
    }
}

struct Shared {
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    device_dirs: Vec<PathBuf>,
    registry: Registry,
    pfs: Arc<dyn Vfs>,
    rules: RuleSet,
    /// Mgmt statistics: (flushes, evictions).
    counters: Mutex<(u64, u64)>,
    /// Monotonic generation source for registry entries.
    generations: AtomicU64,
    /// Flush-pool inbox; `None` once the mount is dropped.
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    /// Jobs enqueued but not yet fully processed.
    pending: Mutex<u64>,
    idle: Condvar,
    /// Per-file flush serialisation (two generations of the same file
    /// must not interleave their PFS writes).
    flush_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
}

impl Shared {
    fn local_path(&self, dev: DeviceRef, rel: &str) -> PathBuf {
        self.device_dirs[dev].join(rel)
    }

    fn next_gen(&self) -> u64 {
        self.generations.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Hand `rel` to the flush pool (no-op for `Keep`).
    fn enqueue_mgmt(&self, mode: MgmtMode, rel: &str, gen: u64) {
        if matches!(mode, MgmtMode::Keep) {
            return;
        }
        let tx = self.tx.lock().expect("tx poisoned");
        if let Some(tx) = tx.as_ref() {
            *self.pending.lock().expect("pending poisoned") += 1;
            let sent = tx.send(Job { mode, rel: rel.to_string(), gen }).is_ok();
            if !sent {
                *self.pending.lock().expect("pending poisoned") -= 1;
                self.idle.notify_all();
            }
        }
    }

    fn flush_lock(&self, rel: &str) -> Arc<Mutex<()>> {
        let mut m = self.flush_locks.lock().expect("flush locks poisoned");
        m.entry(rel.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(())))
            .clone()
    }

    fn release_flush_lock(&self, rel: &str) {
        let mut m = self.flush_locks.lock().expect("flush locks poisoned");
        if let Some(a) = m.get(rel) {
            if Arc::strong_count(a) == 1 {
                m.remove(rel);
            }
        }
    }
}

/// The real-bytes Sea mount.
pub struct SeaFs {
    mountpoint: PathBuf,
    shared: Arc<Shared>,
    select: SelectCfg,
    rng: Mutex<Rng>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl SeaFs {
    /// Mount: builds the hierarchy, spawns the flush pool.
    pub fn mount(cfg: SeaFsConfig) -> Result<SeaFs> {
        if cfg.devices.is_empty() {
            return Err(Error::Config(
                "sea requires at least one fast device (plus the PFS)".into(),
            ));
        }
        let mut hierarchy = Hierarchy::new();
        let mut device_dirs = Vec::new();
        for (dir, tier, cap) in &cfg.devices {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
            hierarchy.add(*tier, *cap, dir.to_string_lossy().into_owned());
            device_dirs.push(dir.clone());
        }
        let accountant = SpaceAccountant::new(&hierarchy);
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(Shared {
            hierarchy,
            accountant,
            device_dirs,
            registry: Registry::new(REGISTRY_SHARDS),
            pfs: cfg.pfs,
            rules: cfg.rules,
            counters: Mutex::new((0, 0)),
            generations: AtomicU64::new(0),
            tx: Mutex::new(Some(tx)),
            pending: Mutex::new(0),
            idle: Condvar::new(),
            flush_locks: Mutex::new(HashMap::new()),
        });
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(FLUSH_WORKERS);
        for w in 0..FLUSH_WORKERS {
            let sh = shared.clone();
            let rx = rx.clone();
            let h = std::thread::Builder::new()
                .name(format!("sea-flush-{w}"))
                .spawn(move || flush_worker(sh, rx))
                .map_err(|e| Error::io("<thread>", e))?;
            workers.push(h);
        }
        Ok(SeaFs {
            mountpoint: cfg.mountpoint,
            shared,
            select: SelectCfg {
                max_file_size: cfg.max_file_size,
                parallel_procs: cfg.parallel_procs,
            },
            rng: Mutex::new(Rng::new(cfg.seed)),
            workers: Mutex::new(workers),
        })
    }

    /// Mount-relative form of `path`, or `None` when outside the mount.
    pub fn rel_of(&self, path: &Path) -> Option<String> {
        path.strip_prefix(&self.mountpoint)
            .ok()
            .map(|r| r.to_string_lossy().into_owned())
    }

    /// Where a mount-relative file currently lives (diagnostics).
    pub fn device_of(&self, rel: &str) -> Option<String> {
        self.shared
            .registry
            .get(rel)
            .map(|e| self.shared.hierarchy.info(e.dev).name.clone())
    }

    /// (flushes, evictions) executed by the flush pool so far.
    pub fn mgmt_counters(&self) -> (u64, u64) {
        *self.shared.counters.lock().expect("counters poisoned")
    }

    /// Prefetch: copy every PFS file under `dir` (mount-relative)
    /// matching the `.sea_prefetchlist` into fast devices.
    pub fn prefetch_dir(&self, dir: &str) -> Result<usize> {
        let names = self.shared.pfs.readdir(Path::new(dir))?;
        let mut n = 0;
        for name in names {
            let rel = if dir.is_empty() { name.clone() } else { format!("{dir}/{name}") };
            if !self.shared.rules.prefetch.matches(&rel) {
                continue;
            }
            let data = self.shared.pfs.read(Path::new(&rel))?;
            if self.place_and_write(&rel, &data, true)?.is_some() {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Core whole-file placement: write `data` to the fastest eligible
    /// device. Returns the chosen device and registry generation, or
    /// `None` when it fell through to the PFS. `already_flushed` marks
    /// prefetched inputs (they came *from* the PFS, so eviction is
    /// always safe).
    fn place_and_write(
        &self,
        rel: &str,
        data: &[u8],
        already_flushed: bool,
    ) -> Result<Option<(DeviceRef, u64)>> {
        let sh = &self.shared;
        // overwrite: free the previous local copy first
        self.drop_local(rel)?;
        let mut rng = self.rng.lock().expect("rng poisoned");
        let pick = select_device(
            &sh.hierarchy,
            &sh.accountant,
            &self.select,
            data.len() as u64,
            &mut rng,
        );
        drop(rng);
        match pick {
            Some(dev) => {
                let p = sh.local_path(dev, rel);
                if let Some(d) = p.parent() {
                    fs::create_dir_all(d).map_err(|e| Error::io(d, e))?;
                }
                fs::write(&p, data).map_err(|e| Error::io(&p, e))?;
                let gen = sh.next_gen();
                sh.registry.insert(
                    rel.to_string(),
                    Entry {
                        dev,
                        size: data.len() as u64,
                        flushed: already_flushed,
                        generation: gen,
                        epoch: gen,
                        writers: 0,
                    },
                );
                Ok(Some((dev, gen)))
            }
            None => {
                sh.pfs.write(Path::new(rel), data)?;
                Ok(None)
            }
        }
    }

    /// Open a writer handle on a mount-relative path: place at open,
    /// debit space as the file grows, defer mgmt to the last close.
    ///
    /// Eligibility at open uses the declared `p·F` floor; a stream that
    /// then outgrows the device fails that `pwrite` with `NoSpace`
    /// rather than spilling mid-file to the PFS (whole-file `write`
    /// does fall through — it knows its size up front). Mid-stream
    /// spill is a tracked follow-on (ROADMAP "VFS layers").
    fn open_writer(&self, rel: &str, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        let sh = &self.shared;
        if mode == OpenMode::ReadWrite {
            // update an existing local copy in place: the entry (and its
            // epoch) is shared with any other open writers
            let gen = sh.next_gen();
            let found = sh.registry.update(rel, |e| {
                e.writers += 1;
                e.flushed = false; // contents are about to change
                e.generation = gen;
                (e.dev, e.epoch)
            });
            if let Some((dev, epoch)) = found {
                let local = sh.local_path(dev, rel);
                match RealFile::open_at(local, OpenMode::ReadWrite) {
                    Ok(file) => {
                        return Ok(Box::new(SeaFile {
                            shared: sh.clone(),
                            rel: rel.to_string(),
                            dev,
                            epoch,
                            file,
                        }))
                    }
                    Err(e) => {
                        // roll the writer count back so mgmt isn't pinned
                        sh.registry.update(rel, |en| {
                            if en.epoch == epoch {
                                en.writers = en.writers.saturating_sub(1);
                            }
                        });
                        return Err(e);
                    }
                }
            }
            if sh.pfs.exists(Path::new(rel)) {
                // no local copy: update the PFS-resident file in place
                return sh.pfs.open(Path::new(rel), mode);
            }
            // brand-new file: fall through to placement
        }
        self.drop_local(rel)?;
        let mut rng = self.rng.lock().expect("rng poisoned");
        // eligibility uses the p·F floor; actual bytes are debited as
        // the handle grows the file
        let pick = select_device(&sh.hierarchy, &sh.accountant, &self.select, 0, &mut rng);
        drop(rng);
        match pick {
            Some(dev) => {
                let p = sh.local_path(dev, rel);
                let file = RealFile::open_at(p, OpenMode::Write)?;
                let gen = sh.next_gen();
                sh.registry.insert(
                    rel.to_string(),
                    Entry {
                        dev,
                        size: 0,
                        flushed: false,
                        generation: gen,
                        epoch: gen,
                        writers: 1,
                    },
                );
                Ok(Box::new(SeaFile {
                    shared: sh.clone(),
                    rel: rel.to_string(),
                    dev,
                    epoch: gen,
                    file,
                }))
            }
            None => sh.pfs.open(Path::new(rel), OpenMode::Write),
        }
    }

    /// `unlink` body; caller holds the per-file flush lock for `rel`.
    fn unlink_locked(&self, path: &Path, rel: &str) -> Result<()> {
        let had_local = self.shared.registry.contains(rel);
        self.drop_local(rel)?;
        // also remove a flushed/PFS copy if present
        let on_pfs = self.shared.pfs.exists(Path::new(rel));
        if on_pfs {
            self.shared.pfs.unlink(Path::new(rel))?;
        }
        if had_local || on_pfs {
            Ok(())
        } else {
            Err(Error::NotFound(path.to_path_buf()))
        }
    }

    /// `rename` body; caller holds the per-file flush lock for `rf`.
    fn rename_locked(&self, rf: &str, rt: &str) -> Result<()> {
        // open writer handles key their registry updates by the old
        // path; moving the entry out from under them would strand their
        // writer counts, so refuse while any are open
        let moved = self.shared.registry.remove_if(rf, |e| e.writers == 0);
        match moved {
            Some(e) => {
                // rename-over-existing replaces the destination: drop its
                // local copy (crediting its space) before the insert, or
                // the old entry's bytes leak from the ledger forever
                self.drop_local(rt)?;
                let (dev, flushed, gen) = (e.dev, e.flushed, e.generation);
                self.shared.registry.insert(rt.to_string(), e);
                let pf = self.shared.local_path(dev, rf);
                let pt = self.shared.local_path(dev, rt);
                if let Some(d) = pt.parent() {
                    fs::create_dir_all(d).map_err(|e| Error::io(d, e))?;
                }
                fs::rename(&pf, &pt).map_err(|e| Error::io(&pf, e))?;
                if flushed && self.shared.pfs.exists(Path::new(rf)) {
                    // a Copy-mode flush left a PFS replica under the old
                    // name — move it along too
                    self.shared.pfs.rename(Path::new(rf), Path::new(rt))?;
                } else if !flushed {
                    // pending mgmt enqueued under the old name was
                    // dropped with the key; re-enqueue for the new
                    let mode = self.shared.rules.mode_for(rt);
                    self.shared.enqueue_mgmt(mode, rt, gen);
                }
                Ok(())
            }
            None if self.shared.registry.contains(rf) => Err(Error::InvalidArg(format!(
                "rename {rf:?}: open writer handles pin the old name"
            ))),
            None => {
                self.shared.pfs.rename(Path::new(rf), Path::new(rt))?;
                // a pre-existing local copy under the destination name
                // would shadow the renamed PFS file on reads — drop it
                self.drop_local(rt)
            }
        }
    }

    /// Remove the local copy of `rel` if any, crediting its space.
    fn drop_local(&self, rel: &str) -> Result<()> {
        let sh = &self.shared;
        let old = sh.registry.remove(rel);
        if let Some(e) = old {
            let p = sh.local_path(e.dev, rel);
            match fs::remove_file(&p) {
                Ok(()) => {}
                Err(err) if err.kind() == std::io::ErrorKind::NotFound => {}
                Err(err) => return Err(Error::io(&p, err)),
            }
            sh.accountant.credit(e.dev, e.size);
        }
        Ok(())
    }
}

/// Writer handle on a device-local file: grows the registry entry (and
/// the space ledger) as bytes land, and triggers deferred management
/// when the last writer closes.
struct SeaFile {
    shared: Arc<Shared>,
    rel: String,
    dev: DeviceRef,
    /// Epoch of the entry this handle's writer count lives in; a
    /// mismatch means the entry was replaced (`drop_local`) and this
    /// handle's file is an orphaned inode — writes still land there,
    /// but registry and ledger must not be touched.
    epoch: u64,
    file: RealFile,
}

impl SeaFile {
    /// Reserve registry/ledger space up to `end` bytes. Size update and
    /// ledger debit happen together under the entry's shard lock, so a
    /// failed reservation never has to roll back a size a concurrent
    /// handle may have extended in the meantime. On exhaustion this is a
    /// hard error (no mid-stream PFS spill — see `open_writer`).
    fn reserve_to(&self, end: u64) -> Result<()> {
        let sh = &self.shared;
        sh.registry
            .update(&self.rel, |e| {
                if e.epoch != self.epoch || end <= e.size {
                    return Ok(()); // superseded or already reserved
                }
                let d = end - e.size;
                if !sh.accountant.try_debit(self.dev, d, 0) {
                    return Err(Error::NoSpace {
                        path: PathBuf::from(&self.rel),
                        needed: d,
                        largest_free: sh.accountant.largest_free(),
                    });
                }
                e.size = end;
                Ok(())
            })
            .unwrap_or(Ok(()))
    }
}

impl VfsFile for SeaFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        self.file.pread(buf, off)
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        if data.is_empty() {
            return Ok(0);
        }
        self.reserve_to(off + data.len() as u64)?;
        self.file.pwrite(data, off)
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        let sh = &self.shared;
        // size update and ledger adjustment are atomic under the shard
        // lock, like reserve_to
        sh.registry
            .update(&self.rel, |e| {
                if e.epoch != self.epoch {
                    return Ok(()); // superseded: no accounting
                }
                if len > e.size {
                    let d = len - e.size;
                    if !sh.accountant.try_debit(self.dev, d, 0) {
                        return Err(Error::NoSpace {
                            path: PathBuf::from(&self.rel),
                            needed: d,
                            largest_free: sh.accountant.largest_free(),
                        });
                    }
                } else {
                    sh.accountant.credit(self.dev, e.size - len);
                }
                e.size = len;
                Ok(())
            })
            .unwrap_or(Ok(()))?;
        self.file.set_len(len)
    }

    fn fsync(&mut self) -> Result<()> {
        self.file.fsync()
    }

    fn len(&self) -> Result<u64> {
        self.file.len()
    }
}

impl Drop for SeaFile {
    fn drop(&mut self) {
        let sh = &self.shared;
        // Membership is by entry identity (epoch), not content
        // generation: a concurrent in-place writer bumps the generation
        // but shares this entry, so the count must still drop; a replaced
        // entry (drop_local) took this handle's count with it, so the
        // superseding entry must not be touched. The last closer enqueues
        // with the entry's *current* generation so the job matches
        // whatever the final writer left behind.
        let mgmt = sh
            .registry
            .update(&self.rel, |e| {
                if e.epoch != self.epoch {
                    return None; // superseded by a newer placement
                }
                e.writers = e.writers.saturating_sub(1);
                if e.writers == 0 {
                    Some(e.generation)
                } else {
                    None
                }
            })
            .flatten();
        if let Some(gen) = mgmt {
            let mode = sh.rules.mode_for(&self.rel);
            sh.enqueue_mgmt(mode, &self.rel, gen);
        }
    }
}

fn flush_worker(sh: Arc<Shared>, rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    loop {
        // hold the inbox lock only while dequeuing; processing overlaps
        // across the pool
        let job = {
            let guard = rx.lock().expect("rx poisoned");
            guard.recv()
        };
        let Ok(job) = job else { break };
        process_job(&sh, &job);
        let mut p = sh.pending.lock().expect("pending poisoned");
        *p -= 1;
        sh.idle.notify_all();
    }
}

fn process_job(sh: &Shared, job: &Job) {
    // serialise per file so two generations never interleave on the PFS
    let lk = sh.flush_lock(&job.rel);
    {
        let _file_guard = lk.lock().expect("flush lock poisoned");
        run_job(sh, job);
    }
    drop(lk);
    sh.release_flush_lock(&job.rel);
}

fn run_job(sh: &Shared, job: &Job) {
    let Some(entry) = sh.registry.get(&job.rel) else { return };
    // A newer write superseded this job (it enqueued its own), or a
    // writer handle is still open (its close will re-enqueue): stand down.
    if entry.generation != job.gen || entry.writers > 0 {
        return;
    }
    let local = sh.local_path(entry.dev, &job.rel);
    let flush = matches!(job.mode, MgmtMode::Copy | MgmtMode::Move);
    let evict = matches!(job.mode, MgmtMode::Remove | MgmtMode::Move);
    if flush && !entry.flushed {
        let Ok(data) = fs::read(&local) else { return };
        // a racing overwrite may have dropped and recreated the local
        // file mid-read: only flush bytes whose size matches the entry
        if data.len() as u64 != entry.size {
            return;
        }
        if sh.pfs.write(Path::new(&job.rel), &data).is_err() {
            return;
        }
        let confirmed = sh
            .registry
            .update(&job.rel, |e| {
                if e.generation == job.gen {
                    e.flushed = true;
                    true
                } else {
                    false
                }
            })
            .unwrap_or(false);
        if !confirmed {
            return; // superseded mid-flush: don't count, don't evict
        }
        sh.counters.lock().expect("counters poisoned").0 += 1;
    }
    if evict {
        // Remove-mode files are dropped unconditionally (the user
        // declared them disposable); Move-mode files must have been
        // flushed first. Either way the generation must still match.
        let removed = sh.registry.remove_if(&job.rel, |e| {
            e.generation == job.gen
                && e.writers == 0
                && (matches!(job.mode, MgmtMode::Remove) || e.flushed)
        });
        if let Some(e) = removed {
            let _ = fs::remove_file(sh.local_path(e.dev, &job.rel));
            sh.accountant.credit(e.dev, e.size);
            sh.counters.lock().expect("counters poisoned").1 += 1;
        }
    }
}

impl Drop for SeaFs {
    fn drop(&mut self) {
        // closing the inbox lets the pool drain the queue and exit
        *self.shared.tx.lock().expect("tx poisoned") = None;
        for h in self.workers.lock().expect("workers poisoned").drain(..) {
            let _ = h.join();
        }
    }
}

impl Vfs for SeaFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        match self.rel_of(path) {
            None => self.shared.pfs.open(path, mode),
            Some(rel) => match mode {
                OpenMode::Read => match self.shared.registry.get(&rel) {
                    Some(e) => {
                        let p = self.shared.local_path(e.dev, &rel);
                        match RealFile::open_at(p, OpenMode::Read) {
                            Ok(f) => Ok(Box::new(f)),
                            // evicted between lookup and open: the flush
                            // that preceded eviction put a PFS copy there
                            Err(Error::NotFound(_)) => {
                                self.shared.pfs.open(Path::new(&rel), OpenMode::Read)
                            }
                            Err(e) => Err(e),
                        }
                    }
                    None => self.shared.pfs.open(Path::new(&rel), OpenMode::Read),
                },
                OpenMode::Write | OpenMode::ReadWrite => self.open_writer(&rel, mode),
            },
        }
    }

    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        match self.rel_of(path) {
            None => self.shared.pfs.read(path),
            Some(rel) => match self.shared.registry.get(&rel) {
                Some(e) => {
                    let p = self.shared.local_path(e.dev, &rel);
                    match fs::read(&p) {
                        Ok(d) => Ok(d),
                        // evicted between lookup and read: fall through
                        // to the flushed PFS copy
                        Err(err) if err.kind() == std::io::ErrorKind::NotFound => {
                            self.shared.pfs.read(Path::new(&rel))
                        }
                        Err(err) => Err(Error::io(&p, err)),
                    }
                }
                None => self.shared.pfs.read(Path::new(&rel)),
            },
        }
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.write(path, data),
            Some(rel) => {
                if let Some((_dev, gen)) = self.place_and_write(&rel, data, false)? {
                    let mode = self.shared.rules.mode_for(&rel);
                    self.shared.enqueue_mgmt(mode, &rel, gen);
                }
                Ok(())
            }
        }
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.unlink(path),
            Some(rel) => {
                // serialise with the flush pool: an in-flight flush of
                // `rel` must finish (or stand down) before we decide
                // whether a PFS copy exists, or a completing flush could
                // recreate the file on the PFS after this unlink
                let lk = self.shared.flush_lock(&rel);
                let res = {
                    let _guard = lk.lock().expect("flush lock poisoned");
                    self.unlink_locked(path, &rel)
                };
                drop(lk);
                self.shared.release_flush_lock(&rel);
                res
            }
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.rel_of(path) {
            None => self.shared.pfs.exists(path),
            Some(rel) => {
                self.shared.registry.contains(&rel)
                    || self.shared.pfs.exists(Path::new(&rel))
            }
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        match self.rel_of(path) {
            None => self.shared.pfs.size(path),
            Some(rel) => match self.shared.registry.get(&rel) {
                Some(e) => Ok(e.size),
                None => self.shared.pfs.size(Path::new(&rel)),
            },
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        match (self.rel_of(from), self.rel_of(to)) {
            (Some(rf), Some(rt)) => {
                // serialise with in-flight flushes of *both* names (a
                // completing job could otherwise leave a PFS copy under
                // `rf`, or recreate the replaced destination `rt`);
                // locks are taken in sorted order so two concurrent
                // renames can't deadlock
                let mut names = vec![rf.clone()];
                if rt != rf {
                    names.push(rt.clone());
                    names.sort();
                }
                let locks: Vec<_> =
                    names.iter().map(|n| self.shared.flush_lock(n)).collect();
                let res = {
                    let _guards: Vec<_> = locks
                        .iter()
                        .map(|l| l.lock().expect("flush lock poisoned"))
                        .collect();
                    self.rename_locked(&rf, &rt)
                };
                drop(locks);
                for n in &names {
                    self.shared.release_flush_lock(n);
                }
                res
            }
            (None, None) => self.shared.pfs.rename(from, to),
            _ => Err(Error::InvalidArg(
                "rename across the sea mount boundary is not supported".into(),
            )),
        }
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        match self.rel_of(path) {
            None => self.shared.pfs.readdir(path),
            Some(rel) => {
                let mut names: Vec<String> = self
                    .shared
                    .pfs
                    .readdir(Path::new(&rel))
                    .unwrap_or_default();
                let prefix = if rel.is_empty() { String::new() } else { format!("{rel}/") };
                for key in self.shared.registry.keys() {
                    if let Some(rest) = key.strip_prefix(&prefix) {
                        if !rest.is_empty() && !rest.contains('/') {
                            names.push(rest.to_string());
                        }
                    }
                }
                names.sort();
                names.dedup();
                Ok(names)
            }
        }
    }

    fn sync_mgmt(&self) -> Result<()> {
        let mut p = self.shared.pending.lock().expect("pending poisoned");
        while *p > 0 {
            p = self.shared.idle.wait(p).expect("pending poisoned");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;

    fn mount(rules: RuleSet, tmpfs_cap: u64) -> (SeaFs, PathBuf, Arc<RealFs>) {
        let root = scratch("seafs");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![
                (root.join("tmpfs"), 0, tmpfs_cap),
                (root.join("disk0"), 1, 100 * MIB),
                (root.join("disk1"), 1, 100 * MIB),
            ],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 2,
            rules,
            seed: 7,
        })
        .unwrap();
        (sea, root, pfs)
    }

    #[test]
    fn writes_go_to_fastest_device_and_read_back() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/derived/a.dat");
        sea.write(p, &vec![7u8; MIB as usize]).unwrap();
        assert!(sea.exists(p));
        assert_eq!(sea.size(p).unwrap(), MIB);
        assert_eq!(sea.device_of("derived/a.dat").unwrap(), root.join("tmpfs").to_string_lossy());
        let data = sea.read(p).unwrap();
        assert!(data.iter().all(|&b| b == 7));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn overflow_spills_to_next_tier_then_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 4 * MIB);
        // floor = p*F = 2 MiB; tmpfs 4 MiB holds 2-3 files of 1 MiB
        let mut devices = Vec::new();
        for i in 0..250 {
            let p = PathBuf::from(format!("/sea/d/f{i:03}.dat"));
            sea.write(&p, &vec![1u8; MIB as usize]).unwrap();
            devices.push(sea.device_of(&format!("d/f{i:03}.dat")));
        }
        let on_tmpfs = devices.iter().flatten().filter(|d| d.contains("tmpfs")).count();
        let on_disk = devices.iter().flatten().filter(|d| d.contains("disk")).count();
        let on_pfs = devices.iter().filter(|d| d.is_none()).count();
        assert!(on_tmpfs >= 2 && on_tmpfs <= 3, "tmpfs {on_tmpfs}");
        assert!(on_disk >= 190, "disk {on_disk}");
        assert!(on_pfs >= 40, "pfs {on_pfs}");
        // the pfs fallback files really are on the pfs
        assert!(pfs.exists(Path::new("d/f249.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn move_mode_flushes_then_evicts() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**_final.dat", "**_final.dat", ""), 10 * MIB);
        let p = Path::new("/sea/out/b_final.dat");
        sea.write(p, &vec![3u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        // after the move: gone locally, present on PFS, still readable
        assert!(sea.device_of("out/b_final.dat").is_none());
        assert!(pfs.exists(Path::new("out/b_final.dat")));
        assert_eq!(sea.read(p).unwrap().len(), MIB as usize);
        assert_eq!(sea.mgmt_counters(), (1, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn copy_mode_keeps_local_copy() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/x.dat");
        sea.write(p, &vec![5u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(sea.device_of("x.dat").is_some(), "local copy kept");
        assert!(pfs.exists(Path::new("x.dat")), "pfs copy exists");
        assert_eq!(sea.mgmt_counters(), (1, 0));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_mode_discards_without_persisting() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("", "*.log", ""), 10 * MIB);
        let p = Path::new("/sea/noise.log");
        sea.write(p, b"scratch").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(!sea.exists(p));
        assert!(!pfs.exists(Path::new("noise.log")));
        assert_eq!(sea.mgmt_counters(), (0, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn eviction_frees_space_for_later_files() {
        // Move everything: space should keep being recycled, so many more
        // files than tmpfs capacity all land on tmpfs eventually
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 4 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/s/f{i}.dat"));
            sea.write(&p, &vec![0u8; MIB as usize]).unwrap();
            sea.sync_mgmt().unwrap(); // drain so space is recycled
        }
        let (fl, ev) = sea.mgmt_counters();
        assert_eq!(fl, 20);
        assert_eq!(ev, 20);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn outside_mount_passes_through_to_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        sea.write(Path::new("plain/file.txt"), b"direct").unwrap();
        assert!(pfs.exists(Path::new("plain/file.txt")));
        assert_eq!(sea.read(Path::new("plain/file.txt")).unwrap(), b"direct");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_and_rename_within_mount() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let a = Path::new("/sea/a.dat");
        let b = Path::new("/sea/b.dat");
        sea.write(a, b"x").unwrap();
        sea.rename(a, b).unwrap();
        assert!(!sea.exists(a));
        assert_eq!(sea.read(b).unwrap(), b"x");
        sea.unlink(b).unwrap();
        assert!(!sea.exists(b));
        assert!(matches!(sea.unlink(b), Err(Error::NotFound(_))));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readdir_merges_local_and_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        pfs.write(Path::new("d/pfs_file"), b"1").unwrap();
        sea.write(Path::new("/sea/d/local_file"), b"2").unwrap();
        let names = sea.readdir(Path::new("/sea/d")).unwrap();
        assert_eq!(names, vec!["local_file", "pfs_file"]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn prefetch_pulls_matching_inputs() {
        let (sea, root, pfs) = mount(
            RuleSet::from_texts("", "", "inputs/*.dat"),
            10 * MIB,
        );
        pfs.write(Path::new("inputs/a.dat"), &vec![1u8; MIB as usize]).unwrap();
        pfs.write(Path::new("inputs/skip.txt"), b"no").unwrap();
        let n = sea.prefetch_dir("inputs").unwrap();
        assert_eq!(n, 1);
        assert!(sea.device_of("inputs/a.dat").is_some());
        assert!(sea.device_of("inputs/skip.txt").is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- handle-based API ---------------------------------------------------

    #[test]
    fn handle_streaming_write_places_and_reads_back() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/h/streamed.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            for k in 0..4u64 {
                f.pwrite_all(&vec![k as u8; 1024], k * 1024).unwrap();
            }
            assert_eq!(f.len().unwrap(), 4096);
        }
        assert!(sea.device_of("h/streamed.dat").is_some(), "placed locally");
        assert_eq!(sea.size(p).unwrap(), 4096);
        let data = sea.read(p).unwrap();
        assert_eq!(data.len(), 4096);
        assert!(data[..1024].iter().all(|&b| b == 0));
        assert!(data[3072..].iter().all(|&b| b == 3));
        // partial read through a handle
        let mut f = sea.open(p, OpenMode::Read).unwrap();
        let mut mid = [0u8; 8];
        f.pread_exact(&mut mid, 2048).unwrap();
        assert!(mid.iter().all(|&b| b == 2));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn streaming_write_defers_mgmt_until_last_close() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let p = Path::new("/sea/defer.dat");
        let mut f = sea.open(p, OpenMode::Write).unwrap();
        f.pwrite_all(&vec![9u8; 4096], 0).unwrap();
        // handle still open: nothing enqueued, nothing flushed
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (0, 0));
        assert!(!pfs.exists(Path::new("defer.dat")));
        assert!(sea.device_of("defer.dat").is_some());
        drop(f);
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1), "move ran at close");
        assert!(pfs.exists(Path::new("defer.dat")));
        assert!(sea.device_of("defer.dat").is_none());
        assert_eq!(sea.read(p).unwrap(), vec![9u8; 4096]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn handle_space_accounting_credits_on_unlink() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let p = Path::new("/sea/acc.dat");
        {
            let mut f = sea.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(&vec![1u8; MIB as usize], 0).unwrap();
            f.set_len(MIB / 2).unwrap(); // shrink credits the ledger
        }
        assert_eq!(sea.size(p).unwrap(), MIB / 2);
        assert_eq!(sea.shared.accountant.total_free(), before - MIB / 2);
        sea.unlink(p).unwrap();
        assert_eq!(sea.shared.accountant.total_free(), before);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_moves_flushed_pfs_copy_too() {
        // regression: a Copy-mode flush used to leave the PFS replica
        // under the *old* name after a rename
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let a = Path::new("/sea/out/a.dat");
        let b = Path::new("/sea/out/b.dat");
        sea.write(a, b"payload").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(pfs.exists(Path::new("out/a.dat")), "flushed before rename");
        sea.rename(a, b).unwrap();
        assert!(!pfs.exists(Path::new("out/a.dat")), "old PFS name gone");
        assert!(pfs.exists(Path::new("out/b.dat")), "PFS copy follows rename");
        assert!(sea.device_of("out/b.dat").is_some());
        assert!(sea.device_of("out/a.dat").is_none());
        assert_eq!(sea.read(b).unwrap(), b"payload");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_of_unflushed_file_keeps_pending_mgmt() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        // write+rename before draining: the flush must follow the new name
        sea.write(Path::new("/sea/tmp.dat"), b"bytes").unwrap();
        sea.rename(Path::new("/sea/tmp.dat"), Path::new("/sea/kept.dat")).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(pfs.exists(Path::new("kept.dat")), "flushed under new name");
        assert!(!pfs.exists(Path::new("tmp.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn overwrite_supersedes_pending_flush() {
        // regression for the write-vs-flush race: the daemon must never
        // persist a half-overwritten entry; the final PFS bytes are the
        // final write's bytes
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/race.dat");
        for round in 0..10u8 {
            sea.write(p, &vec![round; 64 * 1024]).unwrap();
            sea.write(p, &vec![round ^ 0xFF; 64 * 1024]).unwrap();
            sea.sync_mgmt().unwrap();
            let got = pfs.read(Path::new("race.dat")).unwrap();
            assert_eq!(got, vec![round ^ 0xFF; 64 * 1024], "round {round}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_handle_writers_flush_pool_drains() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let sea = Arc::new(sea);
        const THREADS: usize = 8;
        const FILES: usize = 8;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let sea = sea.clone();
                scope.spawn(move || {
                    for i in 0..FILES {
                        let p = PathBuf::from(format!("/sea/w{t}/f{i}.dat"));
                        let mut f = sea.open(&p, OpenMode::Write).unwrap();
                        for k in 0..4u64 {
                            f.pwrite_all(&vec![(t * FILES + i) as u8; 4096], k * 4096)
                                .unwrap();
                        }
                    }
                });
            }
        });
        sea.sync_mgmt().unwrap();
        let (fl, ev) = sea.mgmt_counters();
        assert_eq!(fl, (THREADS * FILES) as u64);
        assert_eq!(ev, (THREADS * FILES) as u64);
        for t in 0..THREADS {
            for i in 0..FILES {
                let rel = format!("w{t}/f{i}.dat");
                assert!(sea.device_of(&rel).is_none(), "{rel} evicted");
                let got = pfs.read(Path::new(&rel)).unwrap();
                assert_eq!(got, vec![(t * FILES + i) as u8; 4 * 4096], "{rel}");
            }
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_open_read_during_flush_and_evict() {
        // readers racing the flush pool must always see either the local
        // or the PFS copy, never an error
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let sea = Arc::new(sea);
        let p = Path::new("/sea/hot.dat");
        sea.write(p, &vec![4u8; 32 * 1024]).unwrap();
        std::thread::scope(|scope| {
            let reader = {
                let sea = sea.clone();
                scope.spawn(move || {
                    for _ in 0..200 {
                        let data = sea.read(Path::new("/sea/hot.dat")).unwrap();
                        assert_eq!(data.len(), 32 * 1024);
                        assert!(data.iter().all(|&b| b == 4));
                    }
                })
            };
            let _ = reader;
        });
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.read(p).unwrap(), vec![4u8; 32 * 1024]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readwrite_handle_updates_in_place() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/upd.dat");
        sea.write(p, b"aaaaaaaa").unwrap();
        sea.sync_mgmt().unwrap();
        assert_eq!(pfs.read(Path::new("upd.dat")).unwrap(), b"aaaaaaaa");
        {
            let mut f = sea.open(p, OpenMode::ReadWrite).unwrap();
            f.pwrite_all(b"BB", 3).unwrap();
        }
        sea.sync_mgmt().unwrap();
        // re-opened for write => re-flushed with the patched bytes
        assert_eq!(sea.read(p).unwrap(), b"aaaBBaaa");
        assert_eq!(pfs.read(Path::new("upd.dat")).unwrap(), b"aaaBBaaa");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_writers_share_entry_and_mgmt_runs_once() {
        // regression: a ReadWrite open bumps the shared entry's
        // generation; the first handle's close must still decrement the
        // writer count or management never fires
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        let p = Path::new("/sea/two.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(b"aaaa", 0).unwrap();
        let mut b = sea.open(p, OpenMode::ReadWrite).unwrap();
        b.pwrite_all(b"bb", 4).unwrap();
        drop(a); // not the last writer: nothing enqueued yet
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (0, 0));
        drop(b); // last close fires the move
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 1));
        assert_eq!(pfs.read(Path::new("two.dat")).unwrap(), b"aaaabb");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stale_writer_does_not_corrupt_superseding_placement() {
        // regression: a handle orphaned by an overwrite (drop_local
        // replaced its entry) must not inflate the new entry's size or
        // the device ledger
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let p = Path::new("/sea/stale.dat");
        let mut a = sea.open(p, OpenMode::Write).unwrap();
        a.pwrite_all(&vec![1u8; 1024], 0).unwrap();
        // supersede the placement while the old handle is still open
        sea.write(p, b"fresh").unwrap();
        // the stale handle writes to its orphaned inode, nothing else
        a.pwrite_all(&vec![2u8; 4096], 0).unwrap();
        assert_eq!(sea.size(p).unwrap(), 5);
        drop(a); // must not enqueue mgmt for the superseded entry
        sea.sync_mgmt().unwrap();
        assert_eq!(sea.mgmt_counters(), (1, 0), "one flush, for the overwrite");
        assert_eq!(sea.read(p).unwrap(), b"fresh");
        assert_eq!(pfs.read(Path::new("stale.dat")).unwrap(), b"fresh");
        assert_eq!(sea.shared.accountant.total_free(), before - 5);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_with_open_writer_is_refused() {
        // an open writer handle keys its registry updates by path, so a
        // rename under it is refused rather than stranding its count
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let a = Path::new("/sea/busy.dat");
        let b = Path::new("/sea/moved.dat");
        let mut f = sea.open(a, OpenMode::Write).unwrap();
        f.pwrite_all(b"x", 0).unwrap();
        assert!(matches!(sea.rename(a, b), Err(Error::InvalidArg(_))));
        drop(f);
        sea.rename(a, b).unwrap();
        assert!(sea.exists(b) && !sea.exists(a));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_over_existing_destination_reclaims_its_space() {
        // regression: replacing a destination entry must credit its
        // bytes back to the ledger and drop its local copy
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let before = sea.shared.accountant.total_free();
        let a = Path::new("/sea/src.dat");
        let b = Path::new("/sea/dst.dat");
        sea.write(b, &vec![1u8; MIB as usize]).unwrap();
        sea.write(a, b"new").unwrap();
        sea.rename(a, b).unwrap();
        assert_eq!(sea.read(b).unwrap(), b"new");
        assert!(!sea.exists(a));
        assert_eq!(sea.shared.accountant.total_free(), before - 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_racing_flush_leaves_no_pfs_copy() {
        // regression: unlink must serialise with in-flight flush jobs or
        // a completing flush resurrects the deleted file on the PFS
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "**", ""), 10 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/u{i}.dat"));
            sea.write(&p, &vec![9u8; 32 * 1024]).unwrap(); // enqueues a move
            sea.unlink(&p).unwrap(); // races the flush pool
            sea.sync_mgmt().unwrap();
            assert!(!sea.exists(&p), "u{i} resurrected locally");
            assert!(!pfs.exists(Path::new(&format!("u{i}.dat"))), "u{i} on pfs");
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}
