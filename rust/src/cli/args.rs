//! Minimal flag parser: `--key value`, `--key=value`, `--flag`, positionals.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::util::bytes::parse_bytes;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positionals: Vec<String>,
    pos_cursor: usize,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse a raw argv slice. Flags may repeat; `--k=v` and `--k v` are
    /// equivalent; a flag followed by another flag (or end) is boolean.
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags
                        .entry(stripped.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                a.positionals.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    /// Consume the next positional argument.
    pub fn next_positional(&mut self) -> Option<String> {
        let p = self.positionals.get(self.pos_cursor).cloned();
        if p.is_some() {
            self.pos_cursor += 1;
        }
        p
    }

    /// All remaining positionals.
    pub fn rest(&self) -> &[String] {
        &self.positionals[self.pos_cursor.min(self.positionals.len())..]
    }

    /// Is a boolean flag present?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Last value of a string flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.get(key)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .ok_or_else(|| Error::InvalidArg(format!("missing required --{key}")))
    }

    /// Integer flag with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::InvalidArg(format!("--{key}: bad integer {s:?}"))),
        }
    }

    /// Float flag with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::InvalidArg(format!("--{key}: bad float {s:?}"))),
        }
    }

    /// Byte-size flag (accepts `617MiB` etc.) with default.
    pub fn bytes_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => {
                parse_bytes(s).ok_or_else(|| Error::InvalidArg(format!("--{key}: bad size {s:?}")))
            }
        }
    }

    /// Comma-separated list of integers (`1,2,4,8`) with default.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| Error::InvalidArg(format!("--{key}: bad integer {t:?}")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        // note: a bare flag followed by a non-flag token greedily takes it
        // as a value, so boolean flags go last or use `--flag=`
        let mut a = Args::parse(&argv(&[
            "sim", "--nodes", "5", "--mode=in-memory", "extra", "--verbose",
        ]));
        assert_eq!(a.next_positional().as_deref(), Some("sim"));
        assert_eq!(a.get("nodes"), Some("5"));
        assert_eq!(a.get("mode"), Some("in-memory"));
        assert!(a.has("verbose"));
        assert_eq!(a.rest(), &["extra".to_string()]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&argv(&["--n", "12", "--x", "2.5", "--size", "617MiB"]));
        assert_eq!(a.usize_or("n", 0).unwrap(), 12);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.bytes_or("size", 0).unwrap(), 617 * crate::util::MIB);
        assert!(a.usize_or("x", 0).is_err());
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn lists_and_repeats() {
        let a = Args::parse(&argv(&["--sweep", "1,2,4", "--tier", "a", "--tier", "b"]));
        assert_eq!(a.usize_list_or("sweep", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_all("tier"), vec!["a", "b"]);
        assert_eq!(a.usize_list_or("none", &[9]).unwrap(), vec![9]);
    }
}
