//! CLI subcommand implementations.

use std::path::PathBuf;
use std::sync::Arc;

use crate::cli::Args;
use crate::config;
use crate::coordinator::{run_pipeline, ExperimentCfg, IoMode, Mode, PipelineCfg};
use crate::coordinator::run_experiment as run_sim_experiment;
use crate::error::{Error, Result};
use crate::model::{lustre_bounds, sea_bounds, ModelParams};
use crate::obs::{self, trace, ObsSnapshot};
use crate::placement::{EngineKind, RuleSet};
use crate::report::{self, describe_run, Scale};
use crate::runtime::Engine;
use crate::sim::spec::ClusterSpec;
use crate::util::bytes::fmt_bw;
use crate::util::{fmt_bytes, MIB};
use crate::serve::{protocol::CountersReply, ServeCfg, Server};
use crate::vfs::{
    DeviceLedger, DeviceSpec, MgmtCounters, PageCache, RateLimitedFs, RealFs, RemoteFs,
    SeaFs, SeaFsConfig, SeaTuning, Vfs,
};
use crate::workload::{dataset, IncrementationSpec};

/// The `sea run` / `sea stat` device layout over a work root: a tmpfs
/// tier-0 plus two tier-1 disk dirs. One builder keeps the two
/// commands reporting on the same mount shape.
fn work_layout(work: &std::path::Path) -> Result<Vec<DeviceSpec>> {
    Ok(vec![
        DeviceSpec::dir(PathBuf::from("/dev/shm/sea_run_tier0"), 0, 2 * 1024 * MIB)?,
        DeviceSpec::dir(work.join("tier1_disk0"), 1, 8 * 1024 * MIB)?,
        DeviceSpec::dir(work.join("tier1_disk1"), 1, 8 * 1024 * MIB)?,
    ])
}

/// Mount tuning: defaults <- `[sea]` section of `--config` <- explicit
/// flags (`--flush-workers`, `--registry-shards`,
/// `--per-member-concurrency`, `--chunk-bytes`, `--copy-window`,
/// `--page-bytes`, `--page-budget`, `--engine`, `--heat-decay`,
/// `--heat-freq-weight`, `--promote-headroom`, `--compress`,
/// `--compress-level`, `--compress-min-ratio`).
fn tuning_from_args(args: &Args) -> Result<SeaTuning> {
    let base = match args.get("config") {
        Some(path) => config::tuning_from_doc(&config::Doc::load(std::path::Path::new(path))?)?,
        None => SeaTuning::default(),
    };
    let engine = match args.get("engine") {
        None => base.engine,
        Some(s) => EngineKind::parse(s).ok_or_else(|| {
            Error::InvalidArg(format!("--engine {s:?}: expected paper | temperature"))
        })?,
    };
    Ok(SeaTuning {
        flush_workers: args.usize_or("flush-workers", base.flush_workers)?,
        registry_shards: args.usize_or("registry-shards", base.registry_shards)?,
        per_member_concurrency: args
            .usize_or("per-member-concurrency", base.per_member_concurrency)?,
        chunk_bytes: args.bytes_or("chunk-bytes", base.chunk_bytes as u64)? as usize,
        copy_window: args.usize_or("copy-window", base.copy_window)?,
        page_bytes: args.bytes_or("page-bytes", base.page_bytes as u64)? as usize,
        page_budget: args.bytes_or("page-budget", base.page_budget)?,
        engine,
        heat_decay: args.f64_or("heat-decay", base.heat_decay)?,
        heat_freq_weight: args.f64_or("heat-freq-weight", base.heat_freq_weight)?,
        promote_headroom_bytes: args
            .bytes_or("promote-headroom", base.promote_headroom_bytes)?,
        compress: base.compress || args.has("compress"),
        compress_level: args.usize_or("compress-level", base.compress_level as usize)?
            as u8,
        compress_min_ratio: args.f64_or("compress-min-ratio", base.compress_min_ratio)?,
    })
}

fn load_spec(args: &Args) -> Result<ClusterSpec> {
    match args.get("cluster") {
        Some(path) => config::load_cluster_spec(std::path::Path::new(path)),
        None => Ok(ClusterSpec::paper_default()),
    }
}

fn workload_from(args: &Args) -> Result<IncrementationSpec> {
    let mut w = IncrementationSpec::paper_default();
    w.blocks = args.usize_or("blocks", w.blocks)?;
    w.file_size = args.bytes_or("file-size", w.file_size)?;
    w.iterations = args.usize_or("iterations", w.iterations)?;
    w.compute_per_iter = args.f64_or("compute", 0.0)?;
    w.read_back = !args.has("no-read-back");
    Ok(w)
}

/// One `sea run` report line for a mapped-mode run's page-cache gauges
/// (shared by the direct and sea branches so they can never diverge).
fn print_pagecache(s: &crate::vfs::PageCacheStats) {
    println!(
        "pagecache  : {} faults, {} hits ({} shared), {} deduped, {} evictions, \
         {} written back, peak resident {}",
        s.faults,
        s.hits,
        s.shared_hits,
        s.frames_deduped,
        s.evictions,
        fmt_bytes(s.writeback_bytes),
        fmt_bytes(s.peak_resident_bytes),
    );
}

/// Resolve the flight-recorder output for `sea run --trace FILE` /
/// `SEA_TRACE=FILE` (flag wins) and arm the recorder when one is set.
/// Pair with [`finish_trace`] on every exit path.
fn trace_target(flag: Option<&str>) -> Option<PathBuf> {
    let out = flag
        .map(String::from)
        .or_else(|| std::env::var("SEA_TRACE").ok())
        .map(PathBuf::from);
    if out.is_some() {
        trace::set_enabled(true);
    }
    out
}

/// Dump the flight recorder to `path` (no-op when tracing is off).
fn finish_trace(path: Option<&std::path::Path>) -> Result<()> {
    if let Some(p) = path {
        let events = trace::dump_to(p).map_err(|e| Error::io(p, e))?;
        println!("trace      : {events} events -> {} (chrome://tracing)", p.display());
    }
    Ok(())
}

fn mode_from(args: &Args) -> Result<Mode> {
    match args.str_or("mode", "sea-in-memory").as_str() {
        "lustre" => Ok(Mode::Lustre),
        "sea-in-memory" | "in-memory" => Ok(Mode::SeaInMemory),
        "sea-flush-all" | "flush-all" | "copy-all" => Ok(Mode::SeaCopyAll),
        other => Err(Error::InvalidArg(format!(
            "--mode {other:?}: expected lustre | sea-in-memory | sea-flush-all"
        ))),
    }
}

/// `sea sim` — one simulated experiment.
pub fn run_sim(args: &mut Args) -> Result<i32> {
    if args.has("help") {
        println!(
            "sea sim [--cluster cfg.toml] [--mode lustre|sea-in-memory|sea-flush-all]\n\
             \x20       [--blocks N] [--file-size 617MiB] [--iterations N]\n\
             \x20       [--nodes N] [--procs N] [--disks N] [--compute SECS] [--seed N]"
        );
        return Ok(0);
    }
    let mut spec = load_spec(args)?;
    spec.nodes = args.usize_or("nodes", spec.nodes)?;
    spec.procs_per_node = args.usize_or("procs", spec.procs_per_node)?;
    spec.disks_per_node = args.usize_or("disks", spec.disks_per_node)?;
    let workload = workload_from(args)?;
    let mode = mode_from(args)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let report = run_sim_experiment(&ExperimentCfg { spec, workload, mode, seed })?;
    print!("{}", describe_run(&report));
    Ok(0)
}

/// `sea experiment` — regenerate a paper figure/table.
pub fn run_experiment_cmd(args: &mut Args) -> Result<i32> {
    let which = match args.next_positional() {
        Some(w) => w,
        None => {
            println!(
                "sea experiment <fig2a|fig2b|fig2c|fig2d|fig3|table2|all>\n\
                 \x20   [--scale paper|quick] [--out results/] [--seed N] [--cluster cfg.toml]"
            );
            return Ok(2);
        }
    };
    let spec = load_spec(args)?;
    let scale = match args.str_or("scale", "paper").as_str() {
        "paper" => Scale::paper(),
        "quick" => Scale::quick(),
        other => {
            let f: f64 = other
                .parse()
                .map_err(|_| Error::InvalidArg(format!("--scale {other:?}")))?;
            Scale { blocks: f }
        }
    };
    let out = PathBuf::from(args.str_or("out", "results"));
    let seed = args.usize_or("seed", 42)? as u64;

    let run_fig = |id: &str| -> Result<()> {
        let fig = match id {
            "fig2a" => report::fig2a(&spec, scale, &[1, 2, 3, 4, 5, 6, 7, 8], seed)?,
            "fig2b" => report::fig2b(&spec, scale, &[1, 2, 3, 4, 5, 6], seed)?,
            "fig2c" => report::fig2c(&spec, scale, &[1, 5, 10, 15], seed)?,
            "fig2d" => report::fig2d(&spec, scale, &[1, 2, 4, 8, 16, 32, 64], seed)?,
            _ => unreachable!(),
        };
        let (csv, txt) = fig.write_to(&out)?;
        println!("{}", fig.to_ascii());
        println!("max speedup: {:.2}x", fig.max_speedup());
        println!("wrote {} and {}", csv.display(), txt.display());
        Ok(())
    };

    match which.as_str() {
        "fig2a" | "fig2b" | "fig2c" | "fig2d" => run_fig(&which)?,
        "fig3" => {
            let rows = report::fig3(&spec, scale, seed)?;
            println!("Fig 3: Sea modes at 5 nodes / 6 procs / 6 disks / 5 iterations\n");
            for (name, r) in &rows {
                println!("--- {name}\n{}", describe_run(r));
            }
            let mut csv = crate::util::csv::Csv::new(vec!["mode", "makespan_s", "app_done_s"]);
            for (name, r) in &rows {
                csv.row(vec![
                    name.clone(),
                    crate::util::csv::f(r.makespan),
                    crate::util::csv::f(r.app_done),
                ]);
            }
            csv.write_to(out.join("fig3.csv"))?;
            println!("wrote {}", out.join("fig3.csv").display());
        }
        "table2" => {
            println!("Table 2 (simulator calibration, from cluster spec):");
            println!("{:<12} {:>8} {:>18}", "layer", "action", "bandwidth");
            let rows = [
                ("tmpfs", "read", spec.mem_read_bw),
                ("tmpfs", "write", spec.mem_write_bw),
                ("local disk", "read", spec.disk_read_bw),
                ("local disk", "write", spec.disk_write_bw),
                ("lustre", "read", spec.lustre.ost_read_bw),
                ("lustre", "write", spec.lustre.ost_write_bw),
            ];
            for (layer, action, bw) in rows {
                println!("{layer:<12} {action:>8} {:>18}", fmt_bw(bw));
            }
            println!("\n(real-device dd-style measurements: `sea bench-devices`)");
        }
        "all" => {
            for id in ["fig2a", "fig2b", "fig2c", "fig2d"] {
                run_fig(id)?;
            }
            let rows = report::fig3(&spec, scale, seed)?;
            for (name, r) in &rows {
                println!("--- {name}\n{}", describe_run(r));
            }
        }
        other => {
            return Err(Error::InvalidArg(format!("unknown experiment {other:?}")));
        }
    }
    Ok(0)
}

/// `sea model` — print analytic bounds for a configuration.
pub fn run_model(args: &mut Args) -> Result<i32> {
    if args.has("help") {
        println!(
            "sea model [--cluster cfg.toml] [--blocks N] [--file-size S] [--iterations N]\n\
             \x20         [--nodes N] [--procs N] [--disks N]"
        );
        return Ok(0);
    }
    let mut spec = load_spec(args)?;
    spec.nodes = args.usize_or("nodes", spec.nodes)?;
    spec.procs_per_node = args.usize_or("procs", spec.procs_per_node)?;
    spec.disks_per_node = args.usize_or("disks", spec.disks_per_node)?;
    let w = workload_from(args)?;
    let params = ModelParams::from_spec(&spec, w.file_size);
    let vol = w.volume();
    let lb = lustre_bounds(&params, &vol);
    let sb = sea_bounds(&params, &vol);
    println!(
        "workload: {} blocks x {} x {} iterations",
        w.blocks,
        fmt_bytes(w.file_size),
        w.iterations
    );
    println!(
        "volumes : D_I {}  D_m {}  D_f {}",
        fmt_bytes(vol.d_i as u64),
        fmt_bytes(vol.d_m as u64),
        fmt_bytes(vol.d_f as u64)
    );
    println!("lustre  : [{:.1}, {:.1}] s  (Eq 5 .. Eq 1)", lb.lower, lb.upper);
    println!("sea     : [{:.1}, {:.1}] s  (Eq 11 .. Eq 7)", sb.lower, sb.upper);
    let b = crate::model::sea_breakdown(&params, &vol);
    println!(
        "sea tier fill: tmpfs w {}  disk w {}  lustre w {}",
        fmt_bytes(b.d_tw as u64),
        fmt_bytes(b.d_gw as u64),
        fmt_bytes(b.d_lw as u64)
    );
    Ok(0)
}

/// `sea bench-devices` — dd-style micro-benchmark of real directories
/// (regenerates Table 2 for this machine).
pub fn run_bench_devices(args: &mut Args) -> Result<i32> {
    let size = args.bytes_or("size", 256 * MIB)?;
    let reps = args.usize_or("reps", 3)?;
    let dirs: Vec<String> = {
        let ds = args.get_all("dir");
        if ds.is_empty() {
            vec!["/dev/shm/sea_bench".to_string(), "/tmp/sea_bench".to_string()]
        } else {
            ds.into_iter().map(String::from).collect()
        }
    };
    println!("{:<24} {:>10} {:>14} {:>14} {:>14}", "dir", "size", "write", "read", "cached read");
    for dir in dirs {
        let root = PathBuf::from(&dir);
        let fs_ = RealFs::new(&root)?;
        let payload = vec![0xA5u8; size as usize];
        let mut wr = Vec::new();
        let mut rd = Vec::new();
        let mut crd = Vec::new();
        for r in 0..reps.max(1) {
            let p = PathBuf::from(format!("bench_{r}.dat"));
            let t0 = std::time::Instant::now();
            fs_.write(&p, &payload)?;
            wr.push(size as f64 / t0.elapsed().as_secs_f64());
            // drop-ish: reading right back is the cached case
            let t0 = std::time::Instant::now();
            let _ = fs_.read(&p)?;
            crd.push(size as f64 / t0.elapsed().as_secs_f64());
            let t0 = std::time::Instant::now();
            let _ = fs_.read(&p)?;
            rd.push(size as f64 / t0.elapsed().as_secs_f64());
            let _ = fs_.unlink(&p);
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "{:<24} {:>10} {:>14} {:>14} {:>14}",
            dir,
            fmt_bytes(size),
            fmt_bw(avg(&wr)),
            fmt_bw(avg(&rd)),
            fmt_bw(avg(&crd)),
        );
        let _ = std::fs::remove_dir_all(&root);
    }
    Ok(0)
}

/// `sea dataset` — generate a real-bytes dataset.
pub fn run_dataset(args: &mut Args) -> Result<i32> {
    let dir = PathBuf::from(args.str_or("dir", "data/bigbrain"));
    let blocks = args.usize_or("blocks", 16)?;
    let rows = args.usize_or("rows", 4096)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let ds = dataset::generate(&dir, blocks, rows * 256, seed)?;
    println!(
        "dataset: {} blocks x {} at {}",
        ds.blocks.len(),
        fmt_bytes(ds.block_bytes()),
        dir.display()
    );
    Ok(0)
}

/// `sea run` — the real-bytes pipeline through a Sea mount vs direct PFS.
pub fn run_real(args: &mut Args) -> Result<i32> {
    if args.has("help") {
        println!(
            "sea run [--artifacts artifacts/] [--work /tmp/sea_run] [--blocks N]\n\
             \x20       [--iterations N] [--workers N] [--mode sea|direct|both]\n\
             \x20       [--connect SOCKET]  # drive a `sea serve` daemon instead of\n\
             \x20       # mounting in-process (same --work root as the daemon)\n\
             \x20       [--pfs-read-mibs N] [--pfs-write-mibs N] [--flush-all]\n\
             \x20       [--io-mode streamed|mmap]  # stride I/O flavour\n\
             \x20       [--config cfg.toml]  # [sea] tuning section\n\
             \x20       [--flush-workers N] [--registry-shards N]\n\
             \x20       [--per-member-concurrency N]  # override the config\n\
             \x20       [--chunk-bytes 1MiB] [--copy-window N]  # DataMover streaming\n\
             \x20       [--page-bytes 64KiB] [--page-budget 64MiB]  # mmap PageCache\n\
             \x20       [--engine paper|temperature]  # placement engine\n\
             \x20       [--heat-decay X] [--heat-freq-weight X] [--promote-headroom S]\n\
             \x20       [--compress] [--compress-level 1..9] [--compress-min-ratio X]\n\
             \x20       # encode cold-tier flushes/spills (see vfs::compress)\n\
             \x20       [--trace FILE]  # flight-recorder dump as Chrome trace JSON\n\
             \x20       # (or SEA_TRACE=FILE; load in chrome://tracing / Perfetto)"
        );
        return Ok(0);
    }
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let work = PathBuf::from(args.str_or("work", "/tmp/sea_run"));
    let blocks = args.usize_or("blocks", 8)?;
    let iterations = args.usize_or("iterations", 5)?;
    let workers = args.usize_or("workers", 2)?;
    let pfs_r = args.f64_or("pfs-read-mibs", 1200.0)? * MIB as f64;
    let pfs_w = args.f64_or("pfs-write-mibs", 120.0)? * MIB as f64;
    let mode = args.str_or("mode", "both");
    let flush_all = args.has("flush-all");
    let io_tok = args.str_or("io-mode", "streamed");
    let io_mode = IoMode::parse(&io_tok).ok_or_else(|| {
        Error::InvalidArg(format!("--io-mode {io_tok:?}: expected streamed | mmap"))
    })?;
    let tuning = tuning_from_args(args)?;
    let trace_out = trace_target(args.get("trace"));

    let engine = Arc::new(Engine::load(&artifacts)?);
    let elems = engine.chunk_elems();
    let ds = dataset::generate(&work.join("pfs/inputs"), blocks, elems, 7)?;
    println!(
        "dataset: {blocks} x {} ({} total)",
        fmt_bytes(ds.block_bytes()),
        fmt_bytes(ds.block_bytes() * blocks as u64)
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    if mode == "direct" || mode == "both" {
        let pfs: Arc<dyn Vfs> = Arc::new(RateLimitedFs::new(
            RealFs::new(work.join("pfs"))?,
            pfs_r,
            pfs_w,
        ));
        // plain backends carry no cache: build one from the same page
        // knobs so a --mode both comparison runs both flavours with an
        // identically-shaped cache
        let direct_cache = (io_mode == IoMode::Mmap)
            .then(|| Arc::new(PageCache::new(tuning.page_bytes, tuning.page_budget)));
        let r = run_pipeline(&PipelineCfg {
            engine: engine.clone(),
            vfs: pfs,
            dataset: ds.clone(),
            mount_prefix: PathBuf::new(),
            iterations,
            workers,
            read_back: true,
            verify: true,
            cleanup_intermediate: true,
            max_open_outputs: 0,
            io_mode,
            page_cache: direct_cache.clone(),
        })?;
        println!(
            "direct-pfs : {:.2}s  ({} read, {} written, {} pjrt calls, {} io)",
            r.makespan,
            fmt_bytes(r.bytes_read),
            fmt_bytes(r.bytes_written),
            r.pjrt_calls,
            io_mode.name()
        );
        if let Some(cache) = direct_cache {
            print_pagecache(&cache.stats());
        }
        results.push(("direct".into(), r.makespan));
    }
    if let Some(sock) = args.get("connect") {
        // Drive an existing `sea serve` daemon instead of mounting
        // in-process. The daemon must serve the same --work root so
        // the freshly generated inputs are visible to it.
        let vfs: Arc<dyn Vfs> = Arc::new(RemoteFs::connect(sock)?);
        let remote_cache = (io_mode == IoMode::Mmap)
            .then(|| Arc::new(PageCache::new(tuning.page_bytes, tuning.page_budget)));
        let r = run_pipeline(&PipelineCfg {
            engine: engine.clone(),
            vfs,
            dataset: ds.clone(),
            mount_prefix: PathBuf::from("/sea"),
            iterations,
            workers,
            read_back: true,
            verify: true,
            cleanup_intermediate: true,
            max_open_outputs: 0,
            io_mode,
            page_cache: remote_cache,
        })?;
        println!(
            "sea-remote : {:.2}s  ({} read, {} written, {} pjrt calls, {} io via {})",
            r.makespan,
            fmt_bytes(r.bytes_read),
            fmt_bytes(r.bytes_written),
            r.pjrt_calls,
            io_mode.name(),
            sock,
        );
        results.push(("sea-remote".into(), r.makespan));
        if results.len() == 2 {
            println!("speedup    : {:.2}x", results[0].1 / results[1].1);
        }
        finish_trace(trace_out.as_deref())?;
        return Ok(0);
    }
    if mode == "sea" || mode == "both" {
        let pfs: Arc<dyn Vfs> = Arc::new(RateLimitedFs::new(
            RealFs::new(work.join("pfs"))?,
            pfs_r,
            pfs_w,
        ));
        let rules = if flush_all {
            RuleSet::copy_all()
        } else {
            RuleSet::in_memory(IncrementationSpec::final_glob())
        };
        let sea = Arc::new(SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: work_layout(&work)?,
            pfs,
            max_file_size: ds.block_bytes(),
            parallel_procs: workers as u64,
            rules,
            seed: 11,
            tuning,
        })?);
        let engine_name = sea.engine_name();
        let vfs: Arc<dyn Vfs> = sea.clone();
        let r = run_pipeline(&PipelineCfg {
            engine: engine.clone(),
            vfs,
            dataset: ds.clone(),
            mount_prefix: PathBuf::from("/sea"),
            iterations,
            workers,
            read_back: true,
            verify: true,
            cleanup_intermediate: true,
            max_open_outputs: 0,
            io_mode,
            page_cache: None, // the mount's own cache: gauges on `sea stat`
        })?;
        println!(
            "sea        : {:.2}s  ({} read, {} written, {} pjrt calls, {} engine, {} io)",
            r.makespan,
            fmt_bytes(r.bytes_read),
            fmt_bytes(r.bytes_written),
            r.pjrt_calls,
            engine_name,
            io_mode.name()
        );
        if io_mode == IoMode::Mmap {
            print_pagecache(&sea.page_cache().stats());
        }
        results.push(("sea".into(), r.makespan));
        let _ = std::fs::remove_dir_all("/dev/shm/sea_run_tier0");
    }
    if results.len() == 2 {
        println!("speedup    : {:.2}x", results[0].1 / results[1].1);
    }
    finish_trace(trace_out.as_deref())?;
    Ok(0)
}

/// `SIGTERM`/`SIGINT` latch for `sea serve` (no `libc` dependency in
/// this crate: `signal(2)` is declared directly — std already links
/// libc).
static SERVE_STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn serve_stop_handler(_sig: i32) {
    SERVE_STOP.store(true, std::sync::atomic::Ordering::SeqCst);
}

fn install_stop_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let h = serve_stop_handler as *const () as usize;
    unsafe {
        signal(SIGTERM, h);
        signal(SIGINT, h);
    }
}

/// `sea serve` — mount the `sea run`/`sea stat` work-root layout once
/// and serve it to any number of client processes over a Unix socket
/// (see [`crate::serve`]). `sea run --connect` / `sea stat --connect`
/// and interposed binaries with `SEA_SOCKET` set are the clients.
/// SIGTERM/SIGINT shut down gracefully: in-flight requests finish,
/// writer handles close (running deferred management), the socket file
/// is removed.
pub fn run_serve(args: &mut Args) -> Result<i32> {
    if args.has("help") {
        println!(
            "sea serve --socket PATH [--config cfg.toml]  # [sea] + [serve] sections\n\
             \x20         [--work /tmp/sea_run] [--max-file-size 617MiB] [--procs N]\n\
             \x20         [--idle-timeout-secs N]  # reap clients silent this long\n\
             \x20         [--no-leases]  # keep reads on the wire (no SCM_RIGHTS fds)\n\
             \x20         [--engine paper|temperature] [--flush-workers N] ...\n\
             \x20         # all `sea stat` mount flags apply; clients must use\n\
             \x20         # the same --work root for input paths to line up\n\
             \x20         # SEA_TRACE=FILE dumps the flight recorder on shutdown"
        );
        return Ok(0);
    }
    let serve_opts = match args.get("config") {
        Some(path) => {
            config::serve_from_doc(&config::Doc::load(std::path::Path::new(path))?)?
        }
        None => config::ServeOpts::default(),
    };
    let socket = match args.get("socket").map(String::from).or(serve_opts.socket) {
        Some(s) => PathBuf::from(s),
        None => {
            return Err(Error::InvalidArg(
                "sea serve needs --socket PATH (or [serve] socket in --config)".into(),
            ))
        }
    };
    let idle_secs =
        args.usize_or("idle-timeout-secs", serve_opts.idle_timeout_secs as usize)?;
    let work = PathBuf::from(args.str_or("work", "/tmp/sea_run"));
    let tuning = tuning_from_args(args)?;
    let trace_out = trace_target(None);
    let rules = RuleSet::load_dir(&work)?;
    let pfs: Arc<dyn Vfs> = Arc::new(RealFs::new(work.join("pfs"))?);
    let sea = Arc::new(SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: work_layout(&work)?,
        pfs,
        max_file_size: args.bytes_or("max-file-size", 617 * MIB)?,
        parallel_procs: args.usize_or("procs", 2)? as u64,
        rules,
        seed: 11,
        tuning,
    })?);
    let mut cfg = ServeCfg::new(&socket);
    cfg.idle_timeout = std::time::Duration::from_secs(idle_secs as u64);
    cfg.lease_fds = serve_opts.lease_fds && !args.has("no-leases");
    let server = Server::spawn(sea.clone(), cfg)?;
    println!(
        "sea serve: {} engine on {} (work root {}); SIGTERM to stop",
        sea.engine_name(),
        socket.display(),
        work.display()
    );
    install_stop_handlers();
    while !SERVE_STOP.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("sea serve: draining and shutting down");
    server.shutdown()?;
    finish_trace(trace_out.as_deref())?;
    Ok(0)
}

/// `sea stat --connect SOCKET --watch SECS`: after the initial full
/// report, poll the daemon every interval and print what changed —
/// request rate, lease grants, and per-op-class latency percentiles
/// over *that interval* (histogram diffs, not cumulative totals; see
/// [`ObsSnapshot::diff`]). Quiet op classes print nothing. Runs until
/// SIGINT/SIGTERM.
fn watch_daemon(fs: &RemoteFs, first: CountersReply, secs: u64) -> Result<()> {
    install_stop_handlers();
    let mut prev = first;
    loop {
        // sleep in 100ms slices so Ctrl-C lands promptly, not at the
        // end of a long interval
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(secs);
        while std::time::Instant::now() < deadline {
            if SERVE_STOP.load(std::sync::atomic::Ordering::SeqCst) {
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let cur = fs.counters()?;
        let dops = cur.ops_served.saturating_sub(prev.ops_served);
        println!(
            "-- +{secs}s: {} ops ({}/s), {} clients connected, +{} fd leases",
            dops,
            dops / secs.max(1),
            cur.clients_connected,
            cur.leases_granted.saturating_sub(prev.leases_granted),
        );
        match (&cur.lat, &prev.lat) {
            (Some(c), Some(p)) => print!("{}", c.diff(p).render()),
            (Some(c), None) => print!("{}", c.render()),
            _ => {}
        }
        prev = cur;
    }
}

/// Render a mount's per-device ledger lines and management counters
/// (the `sea stat` body). `lat` appends one `lat:` percentile line per
/// op class when histograms are available — `None` (an obs-disabled
/// mount, or a pre-v3 daemon) keeps the classic counter-only shape.
fn format_stat(
    engine: &str,
    ledger: &[DeviceLedger],
    c: MgmtCounters,
    lat: Option<&ObsSnapshot>,
) -> String {
    // `logical / physical (ratio)`: what the device's residents decode
    // to over what they actually store — 1.00x everywhere unless a
    // codec ran (see `vfs::compress`)
    let stored = |logical: u64, physical: u64| {
        let ratio =
            if physical > 0 { logical as f64 / physical as f64 } else { 1.0 };
        format!("{} / {} ({:.2}x)", fmt_bytes(logical), fmt_bytes(physical), ratio)
    };
    let mut out = format!("engine : {engine}\n");
    out.push_str(&format!(
        "{:<28} {:>4} {:>10} {:>10} {:>10} {:>11} {:>11}  {}\n",
        "device", "tier", "capacity", "used", "free", "debits", "credits",
        "logical / physical"
    ));
    for l in ledger {
        out.push_str(&format!(
            "{:<28} {:>4} {:>10} {:>10} {:>10} {:>11} {:>11}  {}\n",
            l.name,
            l.tier,
            fmt_bytes(l.capacity),
            fmt_bytes(l.used),
            fmt_bytes(l.free),
            fmt_bytes(l.debits),
            fmt_bytes(l.credits),
            stored(l.logical, l.used),
        ));
    }
    out.push_str(&format!(
        "mgmt   : {} flushes, {} evictions, {} self-spills, {} victim-spills, \
         {} promotions, {} prefetched\n",
        c.flushes, c.evictions, c.self_spills, c.victim_spills, c.promotions, c.prefetched
    ));
    out.push_str(&format!(
        "moved  : flush {}, spill {}, promote {}, prefetch {} \
         (peak copy buffers {})\n",
        stored(c.flush_bytes, c.flush_physical_bytes),
        stored(c.spill_bytes, c.spill_physical_bytes),
        stored(c.promote_bytes, c.promote_physical_bytes),
        stored(c.prefetch_bytes, c.prefetch_physical_bytes),
        fmt_bytes(c.peak_copy_buffer_bytes),
    ));
    out.push_str(&format!(
        "pages  : {} faults, {} hits ({} shared), {} deduped, {} evictions, \
         {} written back (resident {}, peak {})\n",
        c.page_faults,
        c.page_hits,
        c.page_shared_hits,
        c.page_frames_deduped,
        c.page_evictions,
        fmt_bytes(c.page_writeback_bytes),
        fmt_bytes(c.page_resident_bytes),
        fmt_bytes(c.page_peak_resident_bytes),
    ));
    if let Some(l) = lat {
        out.push_str(&l.render());
    }
    out
}

/// `sea stat` — mount a Sea work root (the `sea run` layout: rule
/// dot-files under the work dir, PFS under `work/pfs`) and print its
/// per-device ledger and management counters. The mount-time prefetch
/// pass runs first, so a populated `.sea_prefetchlist` shows up as
/// debits and a `prefetched` count.
///
/// The mount is ephemeral and in-process: ledgers reflect *this*
/// invocation only (device dirs are not scanned for leftovers from
/// earlier runs), and running it concurrently with `sea run` on the
/// same work root shares the tier-0 `/dev/shm` directory.
pub fn run_stat(args: &mut Args) -> Result<i32> {
    if args.has("help") {
        println!(
            "sea stat [--connect SOCKET]  # live counters from a `sea serve` daemon\n\
             \x20        [--watch SECS]  # with --connect: poll and print interval deltas\n\
             \x20        [--work /tmp/sea_run] [--max-file-size 617MiB] [--procs N]\n\
             \x20        [--config cfg.toml] [--engine paper|temperature]\n\
             \x20        [--flush-workers N] [--registry-shards N]\n\
             \x20        [--per-member-concurrency N]\n\
             \x20        [--chunk-bytes 1MiB] [--copy-window N]\n\
             \x20        [--page-bytes 64KiB] [--page-budget 64MiB]\n\
             \x20        [--heat-decay X] [--heat-freq-weight X] [--promote-headroom S]\n\
             \x20        [--compress] [--compress-level 1..9] [--compress-min-ratio X]"
        );
        return Ok(0);
    }
    let watch_secs = args.usize_or("watch", 0)? as u64;
    if let Some(sock) = args.get("connect") {
        // Live daemon: its counters, its ledger, plus who's connected.
        // A v3 daemon also ships its latency histograms; a v2 one
        // leaves `lat` empty and the output degrades to counters only.
        let fs = RemoteFs::connect(sock)?;
        let c = fs.counters()?;
        print!("{}", format_stat(&c.engine, &c.ledger, c.counters, c.lat.as_ref()));
        println!(
            "clients: {} connected ({} total), {} open handles, {} ops served",
            c.clients_connected, c.clients_total, c.open_handles, c.ops_served
        );
        println!(
            "dplane : {} fd leases granted, {} peak in-flight ops on one connection",
            c.leases_granted, c.inflight_peak
        );
        if watch_secs > 0 {
            watch_daemon(&fs, c, watch_secs)?;
        }
        return Ok(0);
    }
    if watch_secs > 0 {
        return Err(Error::InvalidArg(
            "--watch needs --connect SOCKET: an ephemeral local mount has \
             nothing running to watch"
                .into(),
        ));
    }
    let work = PathBuf::from(args.str_or("work", "/tmp/sea_run"));
    let tuning = tuning_from_args(args)?;
    let rules = RuleSet::load_dir(&work)?;
    let pfs: Arc<dyn Vfs> = Arc::new(RealFs::new(work.join("pfs"))?);
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: work_layout(&work)?,
        pfs,
        max_file_size: args.bytes_or("max-file-size", 617 * MIB)?,
        parallel_procs: args.usize_or("procs", 2)? as u64,
        rules,
        seed: 11,
        tuning,
    })?;
    sea.sync_mgmt()?;
    let lat = obs::snapshot();
    print!("{}", format_stat(sea.engine_name(), &sea.ledger(), sea.counters(), Some(&lat)));
    Ok(0)
}

// keep the dispatcher's expected names
pub use run_experiment_cmd as run_experiment;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_stat_renders_ledger_and_counters() {
        let ledger = vec![
            DeviceLedger {
                name: "/dev/shm/tier0".into(),
                tier: 0,
                capacity: 4 * MIB,
                free: 3 * MIB,
                used: MIB,
                debits: 2 * MIB,
                credits: MIB,
                logical: 2 * MIB, // compressed residents: 2x ratio
            },
            DeviceLedger {
                name: "disk0".into(),
                tier: 1,
                capacity: 100 * MIB,
                free: 100 * MIB,
                used: 0,
                debits: 0,
                credits: 0,
                logical: 0,
            },
        ];
        let counters = MgmtCounters {
            flushes: 3,
            evictions: 2,
            self_spills: 1,
            victim_spills: 4,
            promotions: 5,
            prefetched: 6,
            flush_bytes: 3 * MIB,
            spill_bytes: 5 * MIB,
            promote_bytes: MIB,
            prefetch_bytes: 2 * MIB,
            flush_physical_bytes: MIB, // the codec shrank flushes 3x
            spill_physical_bytes: 5 * MIB,
            promote_physical_bytes: MIB,
            prefetch_physical_bytes: 2 * MIB,
            peak_copy_buffer_bytes: 2 * MIB,
            page_faults: 7,
            page_hits: 8,
            page_shared_hits: 5,
            page_frames_deduped: 1,
            page_evictions: 9,
            page_writeback_bytes: MIB,
            page_resident_bytes: MIB / 2,
            page_peak_resident_bytes: MIB,
        };
        let s = format_stat("temperature", &ledger, counters, None);
        assert!(s.contains("engine : temperature"), "{s}");
        assert!(s.contains("/dev/shm/tier0"), "{s}");
        assert!(s.contains("disk0"), "{s}");
        assert!(s.contains("3 flushes"), "{s}");
        assert!(s.contains("4 victim-spills"), "{s}");
        assert!(s.contains("5 promotions"), "{s}");
        assert!(s.contains("6 prefetched"), "{s}");
        assert!(s.contains("moved  : "), "{s}");
        assert!(s.contains("peak copy buffers"), "{s}");
        // ledger lines carry logical / physical (ratio)
        assert!(s.contains("logical / physical"), "{s}");
        assert!(s.contains("2.0 MiB / 1.0 MiB (2.00x)"), "{s}");
        assert!(s.contains("0 B / 0 B (1.00x)"), "{s}");
        // the moved line shows both columns per path
        assert!(s.contains("flush 3.0 MiB / 1.0 MiB (3.00x)"), "{s}");
        assert!(s.contains("spill 5.0 MiB / 5.0 MiB (1.00x)"), "{s}");
        assert!(s.contains("pages  : 7 faults, 8 hits (5 shared), 1 deduped, 9 evictions"), "{s}");
        assert_eq!(
            s.lines().count(),
            1 + 1 + 2 + 1 + 1 + 1,
            "header + table + mgmt + moved + pages (no lat block without histograms)"
        );
    }

    #[test]
    fn format_stat_appends_latency_lines_when_histograms_arrive() {
        let counters = MgmtCounters::default();
        let h = crate::obs::hist::Hist::new();
        for v in [10_000u64, 20_000, 3_000_000] {
            h.record(v);
        }
        let lat = ObsSnapshot {
            metrics: vec![
                (crate::obs::Metric::PreadTier0.index() as u8, h.snapshot()),
                (crate::obs::Metric::DaemonRequest.index() as u8, h.snapshot()),
            ],
        };
        let s = format_stat("paper", &[], counters, Some(&lat));
        assert!(s.contains("lat    : pread.tier0"), "{s}");
        assert!(s.contains("lat    : daemon.req"), "{s}");
        assert!(s.contains("p95"), "{s}");
        // base shape (minus the ledger table rows) plus one lat line
        // per metric
        let base = format_stat("paper", &[], counters, None);
        assert_eq!(s.lines().count(), base.lines().count() + 2, "{s}");
        // an empty snapshot adds nothing
        let empty = ObsSnapshot::default();
        assert_eq!(
            format_stat("paper", &[], counters, Some(&empty)).lines().count(),
            base.lines().count()
        );
    }

    #[test]
    fn tuning_from_args_parses_engine_flag() {
        let argv: Vec<String> =
            ["--engine", "temperature", "--flush-workers", "2"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv);
        let t = tuning_from_args(&args).unwrap();
        assert_eq!(t.engine, EngineKind::Temperature);
        assert_eq!(t.flush_workers, 2);
        let argv: Vec<String> = ["--engine", "bogus"].iter().map(|s| s.to_string()).collect();
        assert!(tuning_from_args(&Args::parse(&argv)).is_err());
    }

    #[test]
    fn tuning_from_args_parses_compress_flags() {
        let argv: Vec<String> =
            ["--compress", "--compress-level", "7", "--compress-min-ratio", "0.9"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let t = tuning_from_args(&Args::parse(&argv)).unwrap();
        assert!(t.compress);
        assert_eq!(t.compress_level, 7);
        assert_eq!(t.compress_min_ratio, 0.9);
        // off by default
        let t = tuning_from_args(&Args::parse(&[])).unwrap();
        assert!(!t.compress);
        assert_eq!(t.compress_level, 3);
    }

    #[test]
    fn tuning_from_args_parses_datamover_flags() {
        let argv: Vec<String> = ["--chunk-bytes", "4MiB", "--copy-window", "3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let t = tuning_from_args(&Args::parse(&argv)).unwrap();
        assert_eq!(t.chunk_bytes, 4 * MIB as usize);
        assert_eq!(t.copy_window, 3);
        // defaults survive when the flags are absent
        let t = tuning_from_args(&Args::parse(&[])).unwrap();
        assert_eq!(t.chunk_bytes, SeaTuning::default().chunk_bytes);
        assert_eq!(t.copy_window, SeaTuning::default().copy_window);
    }

    #[test]
    fn tuning_from_args_parses_pagecache_and_heat_flags() {
        let argv: Vec<String> = [
            "--page-bytes", "16KiB", "--page-budget", "8MiB",
            "--heat-decay", "0.9", "--heat-freq-weight", "2",
            "--promote-headroom", "1MiB",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let t = tuning_from_args(&Args::parse(&argv)).unwrap();
        assert_eq!(t.page_bytes, 16 * 1024);
        assert_eq!(t.page_budget, 8 * MIB);
        assert_eq!(t.heat_decay, 0.9);
        assert_eq!(t.heat_freq_weight, 2.0);
        assert_eq!(t.promote_headroom_bytes, MIB);
        // defaults survive when the flags are absent
        let t = tuning_from_args(&Args::parse(&[])).unwrap();
        assert_eq!(t.page_bytes, SeaTuning::default().page_bytes);
        assert_eq!(t.page_budget, SeaTuning::default().page_budget);
    }

    #[test]
    fn io_mode_tokens_parse() {
        assert_eq!(IoMode::parse("streamed"), Some(IoMode::Streamed));
        assert_eq!(IoMode::parse("mmap"), Some(IoMode::Mmap));
        assert_eq!(IoMode::parse("mapped"), Some(IoMode::Mmap));
        assert_eq!(IoMode::parse("bogus"), None);
        for m in [IoMode::Streamed, IoMode::Mmap] {
            assert_eq!(IoMode::parse(m.name()), Some(m));
        }
    }
}
