//! Hand-rolled CLI (offline substitute for clap — DESIGN.md §2).

mod args;
pub mod commands;

pub use args::Args;

use crate::error::Result;

/// Top-level usage text.
pub const USAGE: &str = "\
sea — reproduction of the Sea data-placement library (Hayot-Sasson 2022)

USAGE:
    sea <COMMAND> [OPTIONS]

COMMANDS:
    run            run the incrementation pipeline on REAL files through a Sea mount
    serve          own a Sea mount as a daemon: serve it to other processes over a Unix socket
    stat           mount a Sea work root and print per-device ledgers + mgmt counters
    sim            run one simulated experiment on the paper-scale cluster
    experiment     regenerate a paper figure/table (fig2a|fig2b|fig2c|fig2d|fig3|table2)
    model          evaluate the analytic performance model (Eqs 1-11)
    bench-devices  dd-style bandwidth micro-benchmark of real storage dirs (Table 2)
    dataset        generate a real-bytes BigBrain-like chunked dataset
    help           show this message

Run `sea <COMMAND> --help` for per-command options.
";

/// Dispatch a CLI invocation; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let mut args = Args::parse(argv);
    let cmd = match args.next_positional() {
        Some(c) => c,
        None => {
            print!("{USAGE}");
            return Ok(2);
        }
    };
    match cmd.as_str() {
        "run" => commands::run_real(&mut args),
        "serve" => commands::run_serve(&mut args),
        "stat" => commands::run_stat(&mut args),
        "sim" => commands::run_sim(&mut args),
        "experiment" => commands::run_experiment(&mut args),
        "model" => commands::run_model(&mut args),
        "bench-devices" => commands::run_bench_devices(&mut args),
        "dataset" => commands::run_dataset(&mut args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("sea: unknown command {other:?}\n");
            print!("{USAGE}");
            Ok(2)
        }
    }
}
