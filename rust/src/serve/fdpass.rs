//! `SCM_RIGHTS` fd passing for the zero-copy data plane.
//!
//! The daemon leases a dup'd `O_RDONLY` file descriptor to a read-only
//! client by sending it as ancillary data **in the same `sendmsg(2)`
//! as the `Open` reply frame**: stream ordering alone then associates
//! the fd with the frame on the receiving side — no out-of-band
//! channel, no fd table synchronization. The client reader drains fds
//! with `MSG_CMSG_CLOEXEC` so leases never outlive an `exec`.
//!
//! The sea crate deliberately carries no external dependencies, so the
//! small slice of the Linux x86-64 ABI this needs (`msghdr`,
//! `cmsghdr`, `sendmsg`, `recvmsg`) is declared here directly. The
//! daemon only ever attaches **one** fd per frame; the receive side
//! still parses the control buffer generically because one `recvmsg`
//! may observe ancillary data from a burst of replies.

use std::io;
use std::mem::size_of;
use std::os::fd::{FromRawFd, OwnedFd, RawFd};

#[repr(C)]
struct IoVec {
    base: *mut u8,
    len: usize,
}

#[repr(C)]
struct MsgHdr {
    name: *mut u8,
    namelen: u32,
    iov: *mut IoVec,
    iovlen: usize,
    control: *mut u8,
    controllen: usize,
    flags: i32,
}

#[repr(C)]
struct CmsgHdr {
    len: usize,
    level: i32,
    ty: i32,
}

const SOL_SOCKET: i32 = 1;
const SCM_RIGHTS: i32 = 1;
/// Suppress `SIGPIPE` when the peer vanished mid-reply; the `EPIPE`
/// errno is handled like any other write error.
const MSG_NOSIGNAL: i32 = 0x4000;
/// Received fds are opened close-on-exec atomically.
const MSG_CMSG_CLOEXEC: i32 = 0x4000_0000;

/// `CMSG_LEN(sizeof(int))`: header (16 on LP64) + one 4-byte fd.
const CMSG_ONE_FD_LEN: usize = size_of::<CmsgHdr>() + 4;
/// `CMSG_SPACE(sizeof(int))`: [`CMSG_ONE_FD_LEN`] rounded up to the
/// 8-byte cmsg alignment.
const CMSG_ONE_FD_SPACE: usize = (CMSG_ONE_FD_LEN + 7) & !7;
/// Control-buffer room on the receive side; generous because one
/// `recvmsg` can surface ancillary data for several coalesced replies.
const RECV_CMSG_SPACE: usize = CMSG_ONE_FD_SPACE * 16;

extern "C" {
    fn sendmsg(fd: i32, msg: *const MsgHdr, flags: i32) -> isize;
    fn recvmsg(fd: i32, msg: *mut MsgHdr, flags: i32) -> isize;
}

/// Send the concatenation of `bufs` over `sock`, attaching `fd` (when
/// given) as a single `SCM_RIGHTS` cmsg riding the **first** byte of
/// the payload. Partial sends are resumed plain — the ancillary data
/// goes out exactly once, with the first successful `sendmsg`.
pub fn send_frame_fd(sock: RawFd, bufs: &[&[u8]], fd: Option<RawFd>) -> io::Result<()> {
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    if total == 0 {
        return Ok(());
    }
    let mut sent = 0usize;
    let mut fd_pending = fd;
    while sent < total {
        // Rebuild the iovec list past what already went out.
        let mut skip = sent;
        let mut iov: Vec<IoVec> = Vec::with_capacity(bufs.len());
        for b in bufs {
            if skip >= b.len() {
                skip -= b.len();
                continue;
            }
            iov.push(IoVec {
                base: unsafe { b.as_ptr().add(skip) } as *mut u8,
                len: b.len() - skip,
            });
            skip = 0;
        }
        let mut control = [0u8; CMSG_ONE_FD_SPACE];
        let mut msg = MsgHdr {
            name: std::ptr::null_mut(),
            namelen: 0,
            iov: iov.as_mut_ptr(),
            iovlen: iov.len(),
            control: std::ptr::null_mut(),
            controllen: 0,
            flags: 0,
        };
        if let Some(rfd) = fd_pending {
            unsafe {
                let hdr = control.as_mut_ptr() as *mut CmsgHdr;
                (*hdr).len = CMSG_ONE_FD_LEN;
                (*hdr).level = SOL_SOCKET;
                (*hdr).ty = SCM_RIGHTS;
                std::ptr::copy_nonoverlapping(
                    (&rfd as *const RawFd).cast::<u8>(),
                    control.as_mut_ptr().add(size_of::<CmsgHdr>()),
                    4,
                );
            }
            msg.control = control.as_mut_ptr();
            msg.controllen = CMSG_ONE_FD_SPACE;
        }
        let n = unsafe { sendmsg(sock, &msg, MSG_NOSIGNAL) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::WriteZero,
                "sendmsg wrote zero bytes",
            ));
        }
        fd_pending = None; // the cmsg rode the first successful send
        sent += n as usize;
    }
    Ok(())
}

/// `recvmsg(2)` into `buf`, appending any `SCM_RIGHTS` fds (opened
/// close-on-exec) to `fds` in stream order. Returns the byte count
/// read (`0` means EOF).
pub fn recv_with_fds(
    sock: RawFd,
    buf: &mut [u8],
    fds: &mut Vec<OwnedFd>,
) -> io::Result<usize> {
    loop {
        let mut iov = IoVec { base: buf.as_mut_ptr(), len: buf.len() };
        let mut control = [0u8; RECV_CMSG_SPACE];
        let mut msg = MsgHdr {
            name: std::ptr::null_mut(),
            namelen: 0,
            iov: &mut iov,
            iovlen: 1,
            control: control.as_mut_ptr(),
            controllen: RECV_CMSG_SPACE,
            flags: 0,
        };
        let n = unsafe { recvmsg(sock, &mut msg, MSG_CMSG_CLOEXEC) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        collect_fds(&control, msg.controllen, fds);
        return Ok(n as usize);
    }
}

/// Walk the cmsg chain in `control[..used]` and claim every
/// `SCM_RIGHTS` fd. Unknown cmsg types are skipped; a malformed length
/// ends the walk (nothing after it can be trusted).
fn collect_fds(control: &[u8], used: usize, out: &mut Vec<OwnedFd>) {
    let used = used.min(control.len());
    let mut off = 0usize;
    while off + size_of::<CmsgHdr>() <= used {
        let (len, level, ty) = unsafe {
            let hdr = &*(control.as_ptr().add(off) as *const CmsgHdr);
            (hdr.len, hdr.level, hdr.ty)
        };
        if len < size_of::<CmsgHdr>() || off + len > used {
            break;
        }
        if level == SOL_SOCKET && ty == SCM_RIGHTS {
            let data = off + size_of::<CmsgHdr>();
            for i in 0..(len - size_of::<CmsgHdr>()) / 4 {
                let mut raw = [0u8; 4];
                raw.copy_from_slice(&control[data + i * 4..data + i * 4 + 4]);
                let fd = RawFd::from_ne_bytes(raw);
                if fd >= 0 {
                    out.push(unsafe { OwnedFd::from_raw_fd(fd) });
                }
            }
        }
        let adv = (len + 7) & !7; // CMSG_ALIGN
        if adv == 0 {
            break;
        }
        off += adv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
    use std::os::fd::{AsRawFd, IntoRawFd};
    use std::os::unix::net::UnixStream;

    #[test]
    fn frame_bytes_and_fd_cross_a_socketpair_together() {
        let dir = crate::vfs::testutil::scratch("fdpass_rt");
        let path = dir.join("leased.dat");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"leased inode content").unwrap();
        drop(f);
        let src = std::fs::File::open(&path).unwrap();

        let (a, b) = UnixStream::pair().unwrap();
        let hdr = [7u8; 12];
        let payload = b"open-reply-payload".to_vec();
        send_frame_fd(
            a.as_raw_fd(),
            &[&hdr, &payload],
            Some(src.as_raw_fd()),
        )
        .unwrap();
        drop(src); // the dup'd fd in flight must keep the inode readable

        let mut got = vec![0u8; hdr.len() + payload.len()];
        let mut fds = Vec::new();
        let mut read = 0;
        while read < got.len() {
            let n = recv_with_fds(b.as_raw_fd(), &mut got[read..], &mut fds).unwrap();
            assert!(n > 0, "EOF before the frame completed");
            read += n;
        }
        assert_eq!(&got[..12], &hdr);
        assert_eq!(&got[12..], &payload[..]);
        assert_eq!(fds.len(), 1, "exactly one leased fd");

        let mut leased = std::fs::File::from(fds.pop().unwrap());
        leased.seek(SeekFrom::Start(0)).unwrap();
        let mut body = String::new();
        leased.read_to_string(&mut body).unwrap();
        assert_eq!(body, "leased inode content");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plain_frames_carry_no_fds() {
        let (a, b) = UnixStream::pair().unwrap();
        send_frame_fd(a.as_raw_fd(), &[b"just-bytes"], None).unwrap();
        let mut buf = [0u8; 32];
        let mut fds = Vec::new();
        let n = recv_with_fds(b.as_raw_fd(), &mut buf, &mut fds).unwrap();
        assert_eq!(&buf[..n], b"just-bytes");
        assert!(fds.is_empty());
    }

    #[test]
    fn received_fds_are_cloexec() {
        let (a, b) = UnixStream::pair().unwrap();
        let f = std::fs::File::open("/dev/null").unwrap();
        send_frame_fd(a.as_raw_fd(), &[b"x"], Some(f.as_raw_fd())).unwrap();
        let mut buf = [0u8; 8];
        let mut fds = Vec::new();
        recv_with_fds(b.as_raw_fd(), &mut buf, &mut fds).unwrap();
        let fd = fds.pop().unwrap().into_raw_fd();
        // F_GETFD → FD_CLOEXEC must be set by MSG_CMSG_CLOEXEC.
        extern "C" {
            fn fcntl(fd: i32, cmd: i32, ...) -> i32;
        }
        const F_GETFD: i32 = 1;
        const FD_CLOEXEC: i32 = 1;
        let flags = unsafe { fcntl(fd, F_GETFD) };
        assert!(flags >= 0 && flags & FD_CLOEXEC != 0, "flags: {flags}");
        drop(unsafe { OwnedFd::from_raw_fd(fd) });
    }
}
