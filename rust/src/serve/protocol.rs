//! The Sea service wire protocol: compact length-prefixed binary frames
//! over a Unix domain socket. No external crates — every integer is
//! little-endian, every string is length-prefixed UTF-8.
//!
//! ## Frame format
//!
//! Every message (request or response) travels as one frame:
//!
//! | bytes | field                                        |
//! |-------|----------------------------------------------|
//! | 4     | payload length `n` (u32 LE, `<=` [`MAX_FRAME`]) |
//! | 8     | request id (u64 LE)                          |
//! | n     | payload                                      |
//!
//! The **request id** is chosen by the client (0 is reserved for the
//! handshake) and echoed verbatim on the matching response. Because
//! responses carry the id, the daemon may answer **out of order** and
//! a client may keep many requests in flight on one connection — the
//! pipelining that lets every `RemoteFile` of a process share a single
//! mux'd connection. Frames are written vectored (`writev`
//! header+payload) so large payloads are never copied into a staging
//! buffer.
//!
//! A **request** payload is `[opcode u8][operands…]`; a **response**
//! payload is `[status u8][gen u64][body…]` where status 0 = ok and
//! status 1 = error. The `gen` slot piggybacks the daemon-side
//! [`crate::vfs::VfsFile::map_sync`] generation of the handle the
//! request touched (0 for path-level ops): a client that sees it move
//! knows another process relocated the file (e.g. a mid-stream spill)
//! and must invalidate any cached/mapped pages it holds — the
//! cross-process analogue of the in-process page-cache generation key.
//! The same bump revokes any fd **lease** the client holds on the
//! file (see below).
//!
//! ## Data-plane frames
//!
//! | frame | layout | notes |
//! |-------|--------|-------|
//! | `Open` reply | `[handle u64][ident?][lease?: u64 gen]` | when `lease` is present, **one dup'd `O_RDONLY` fd rides this very frame** as `SCM_RIGHTS` ancillary data (sent in the same `sendmsg`, so stream order associates them). The client preads the leased fd directly — zero round trips — until any response piggybacks `gen > lease`. |
//! | `Readdir` request | `[path str][token u64]` | `token` is the continuation cursor (0 starts the listing). |
//! | `Names` reply | `[count u32][name str…][next u64]` | `next == 0` means the listing is complete; otherwise pass it back as the next `token`. Pages keep frames far under [`MAX_IO`] no matter how wide the directory is. |
//! | `Hello` reply | `[version u32][chunk_bytes u64]` | `chunk_bytes` is the daemon's streamed-transfer chunk size — the client uses it as its default readahead window. |
//!
//! Primitive encodings (all little-endian):
//!
//! | type  | encoding                                   |
//! |-------|--------------------------------------------|
//! | `u8`/`u32`/`u64`/`u128` | fixed-width LE          |
//! | `str` | `u32` byte length + UTF-8 bytes            |
//! | `bytes` | `u32` length + raw bytes                 |
//! | `[T]` | `u32` count + each element                 |
//!
//! ## Handshake
//!
//! The first frame on a connection must be [`Request::Hello`] carrying
//! [`PROTOCOL_VERSION`]; the daemon answers with its own version on
//! success or an [`ErrCode::VersionMismatch`] error frame (and closes)
//! so a mismatched client fails with a clear message instead of
//! decoding garbage.
//!
//! ## Error frames
//!
//! `[1u8][gen u64][code u8][msg str][path str][a u64][b u64]` — `code`
//! maps back onto the crate's typed [`Error`] variants on the client
//! (`a`/`b` carry `NoSpace`'s needed/largest-free bytes; zero
//! elsewhere), so a daemon-side `NotFound` is a client-side
//! `Error::NotFound`, not a stringly-typed surprise.

use std::io::{Read, Write};
use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::obs::{hist, ObsSnapshot};
use crate::vfs::{DeviceLedger, MgmtCounters, OpenMode};

/// Protocol revision. Bump on any wire-visible change; the daemon
/// accepts clients speaking any revision in
/// [`MIN_PROTOCOL_VERSION`]..=[`PROTOCOL_VERSION`] and serves each
/// connection at the client's revision, so an old client keeps working
/// against a new daemon (it simply never sees the newer reply fields).
///
/// v2: request ids in the frame header (pipelining), fd leases on
/// `Open` replies, paginated `Readdir`, `Mkdir`, and the readahead
/// hint in the `Hello` reply.
///
/// v3: the `Counters` reply may carry an optional latency-histogram
/// tail ([`CountersReply::lat`]) — appended after the v2 fields, so a
/// v2 decoder that stops early still consumes a valid frame, and a v3
/// decoder treats "no bytes left" as "no histograms".
pub const PROTOCOL_VERSION: u32 = 3;

/// Oldest client revision the daemon still serves (see
/// [`PROTOCOL_VERSION`]).
pub const MIN_PROTOCOL_VERSION: u32 = 2;

/// Largest single-request I/O payload the daemon accepts or serves.
/// Bigger preads return short (positioned-I/O semantics allow it);
/// bigger pwrites are truncated client-side to this size and report a
/// short write, which `pwrite_all` loops over.
pub const MAX_IO: usize = 8 * 1024 * 1024;

/// Hard ceiling on one frame's payload: `MAX_IO` plus generous header
/// room. A peer announcing more is protocol-broken — the connection is
/// dropped rather than allocating unbounded memory.
pub const MAX_FRAME: usize = MAX_IO + 64 * 1024;

// --- opcodes ---------------------------------------------------------------

const OP_HELLO: u8 = 0x01;
const OP_OPEN: u8 = 0x02;
const OP_PREAD: u8 = 0x03;
const OP_PWRITE: u8 = 0x04;
const OP_SET_LEN: u8 = 0x05;
const OP_FSYNC: u8 = 0x06;
const OP_CLOSE: u8 = 0x07;
const OP_STAT: u8 = 0x08;
const OP_READDIR: u8 = 0x09;
const OP_RENAME: u8 = 0x0A;
const OP_UNLINK: u8 = 0x0B;
const OP_MAP_SYNC: u8 = 0x0C;
const OP_NOTE_FAULT: u8 = 0x0D;
const OP_COUNTERS: u8 = 0x0E;
const OP_LEN: u8 = 0x0F;
const OP_SYNC_MGMT: u8 = 0x10;
const OP_MKDIR: u8 = 0x11;

/// One client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Version handshake; must be the first frame on a connection.
    Hello { version: u32 },
    /// Open a handle on `path` (daemon-side `Vfs::open`).
    Open { mode: OpenMode, path: String },
    /// Positioned read of up to `len` bytes at `off`.
    Pread { handle: u64, off: u64, len: u32 },
    /// Positioned write of `data` at `off`.
    Pwrite { handle: u64, off: u64, data: Vec<u8> },
    /// Truncate/extend to exactly `len`.
    SetLen { handle: u64, len: u64 },
    /// Durably persist the handle.
    Fsync { handle: u64 },
    /// Release the handle (daemon runs deferred management).
    Close { handle: u64 },
    /// Current handle length.
    Len { handle: u64 },
    /// Size of the file at `path` (also the exists probe).
    Stat { path: String },
    /// One page of names under directory `path`, starting at
    /// continuation cursor `token` (0 = from the top). The reply's
    /// `next` field chains the pages.
    Readdir { path: String, token: u64 },
    /// Rename `from` to `to`.
    Rename { from: String, to: String },
    /// Remove `path`.
    Unlink { path: String },
    /// Ensure directory `path` exists (`create_dir_all` semantics —
    /// succeeding when it already does, hence idempotent).
    Mkdir { path: String },
    /// Refresh the handle against the registry; the response's `gen`
    /// slot carries the result.
    MapSync { handle: u64 },
    /// A client-side page fault on `[off, off+len)` — feeds the
    /// daemon's placement engine heat.
    NoteFault { handle: u64, off: u64, len: u64 },
    /// Live daemon counters + ledger + per-client stats.
    Counters,
    /// Block until the daemon's background management drains.
    SyncMgmt,
}

/// Error category carried in an error frame; maps onto [`Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrCode {
    /// Underlying I/O failure on the daemon side.
    Io = 1,
    /// `Error::NotFound`.
    NotFound = 2,
    /// `Error::NoSpace` (operands carry needed / largest-free).
    NoSpace = 3,
    /// `Error::OutsideMount`.
    OutsideMount = 4,
    /// `Error::InvalidArg`.
    InvalidArg = 5,
    /// The request named a handle this connection does not hold.
    BadHandle = 6,
    /// Handshake version differed from the daemon's.
    VersionMismatch = 7,
    /// The daemon is draining for shutdown.
    Shutdown = 8,
    /// Anything else (config/integrity/… collapsed to a message).
    Other = 9,
}

impl ErrCode {
    fn from_u8(b: u8) -> ErrCode {
        match b {
            1 => ErrCode::Io,
            2 => ErrCode::NotFound,
            3 => ErrCode::NoSpace,
            4 => ErrCode::OutsideMount,
            5 => ErrCode::InvalidArg,
            6 => ErrCode::BadHandle,
            7 => ErrCode::VersionMismatch,
            8 => ErrCode::Shutdown,
            _ => ErrCode::Other,
        }
    }
}

/// A typed error as it travels on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Category (drives the client-side [`Error`] reconstruction).
    pub code: ErrCode,
    /// Human-readable message.
    pub msg: String,
    /// Path the operation touched, when one exists.
    pub path: String,
    /// `NoSpace`: bytes needed. Zero otherwise.
    pub a: u64,
    /// `NoSpace`: largest free block. Zero otherwise.
    pub b: u64,
}

impl WireError {
    /// Encode a daemon-side [`Error`] for the wire.
    pub fn from_error(e: &Error) -> WireError {
        let (code, msg, path, a, b) = match e {
            Error::Io { path, source } => {
                (ErrCode::Io, source.to_string(), path.display().to_string(), 0, 0)
            }
            Error::NotFound(p) => {
                (ErrCode::NotFound, String::new(), p.display().to_string(), 0, 0)
            }
            Error::NoSpace { path, needed, largest_free } => (
                ErrCode::NoSpace,
                String::new(),
                path.display().to_string(),
                *needed,
                *largest_free,
            ),
            Error::OutsideMount(p) => {
                (ErrCode::OutsideMount, String::new(), p.display().to_string(), 0, 0)
            }
            Error::InvalidArg(m) => (ErrCode::InvalidArg, m.clone(), String::new(), 0, 0),
            other => (ErrCode::Other, other.to_string(), String::new(), 0, 0),
        };
        WireError { code, msg, path, a, b }
    }

    /// Reconstruct the typed [`Error`] on the client.
    pub fn into_error(self) -> Error {
        match self.code {
            ErrCode::Io => Error::io(
                PathBuf::from(self.path),
                std::io::Error::new(std::io::ErrorKind::Other, self.msg),
            ),
            ErrCode::NotFound => Error::NotFound(PathBuf::from(self.path)),
            ErrCode::NoSpace => Error::NoSpace {
                path: PathBuf::from(self.path),
                needed: self.a,
                largest_free: self.b,
            },
            ErrCode::OutsideMount => Error::OutsideMount(PathBuf::from(self.path)),
            ErrCode::InvalidArg => Error::InvalidArg(self.msg),
            ErrCode::BadHandle => {
                Error::Daemon(format!("stale/unknown remote handle: {}", self.msg))
            }
            ErrCode::VersionMismatch => Error::Daemon(format!(
                "protocol version mismatch: {} (client speaks {PROTOCOL_VERSION})",
                self.msg
            )),
            ErrCode::Shutdown => {
                Error::DaemonGone(format!("daemon shutting down: {}", self.msg))
            }
            ErrCode::Other => Error::Daemon(self.msg),
        }
    }
}

/// Success payload of a response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Body {
    /// No payload beyond the piggybacked generation.
    Unit,
    /// Handshake echo: the daemon's protocol version plus its
    /// streamed-transfer chunk size, which the client adopts as the
    /// default readahead window.
    Hello { version: u32, chunk_bytes: u64 },
    /// New handle id plus the daemon handle's frame-sharing identity
    /// (`None` when the backend cannot name one). `lease` is the map
    /// generation the lease was minted at; when present, exactly one
    /// dup'd `O_RDONLY` fd rides this frame as `SCM_RIGHTS` ancillary
    /// data.
    Open { handle: u64, ident: Option<u128>, lease: Option<u64> },
    /// Pread result.
    Data(Vec<u8>),
    /// Pwrite result: bytes accepted.
    Written(u32),
    /// Len/Stat result.
    Size(u64),
    /// One Readdir page; `next` is the continuation token for the
    /// following page (0 = listing complete).
    Names { names: Vec<String>, next: u64 },
    /// Counters snapshot.
    Counters(Box<CountersReply>),
}

/// The `Counters` response: everything `sea stat --connect` prints.
#[derive(Debug, Clone, PartialEq)]
pub struct CountersReply {
    /// Placement engine driving the daemon's mount.
    pub engine: String,
    /// Per-device ledger lines.
    pub ledger: Vec<DeviceLedger>,
    /// Cumulative management counters.
    pub counters: MgmtCounters,
    /// Clients connected right now.
    pub clients_connected: u64,
    /// Connections accepted since the daemon started.
    pub clients_total: u64,
    /// Remote handles currently open across all clients.
    pub open_handles: u64,
    /// Requests served since the daemon started.
    pub ops_served: u64,
    /// Fd leases handed out since the daemon started (each one a
    /// read path that bypasses the wire entirely).
    pub leases_granted: u64,
    /// High-water mark of concurrently executing requests on any one
    /// connection — how much the pipelined executor is actually used.
    pub inflight_peak: u64,
    /// Daemon-side latency histograms (protocol ≥ 3). `None` when the
    /// connection speaks v2, when the daemon predates them, or when
    /// the daemon disabled recording — `sea stat --connect` then
    /// degrades to counters-only.
    pub lat: Option<ObsSnapshot>,
}

/// One response: the piggybacked map generation plus the outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// Daemon-side `map_sync` generation of the handle the request
    /// touched (0 for path-level ops). See the module docs.
    pub gen: u64,
    /// Success payload or typed error.
    pub body: std::result::Result<Body, WireError>,
}

// --- primitive encoders ----------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u128(buf: &mut Vec<u8>, v: u128) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}
fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

/// Cursor over a received payload with typed, bounds-checked readers.
struct Cur<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, at: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            return Err(Error::Daemon(format!(
                "truncated frame: wanted {n} bytes at {}, have {}",
                self.at,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME {
            return Err(Error::Daemon(format!("oversized string: {n} bytes")));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Daemon("non-UTF-8 string in frame".into()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME {
            return Err(Error::Daemon(format!("oversized byte blob: {n} bytes")));
        }
        Ok(self.take(n)?.to_vec())
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }
    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            return Err(Error::Daemon(format!(
                "trailing garbage in frame: {} of {} bytes consumed",
                self.at,
                self.buf.len()
            )));
        }
        Ok(())
    }
}

fn mode_to_u8(m: OpenMode) -> u8 {
    match m {
        OpenMode::Read => 0,
        OpenMode::Write => 1,
        OpenMode::ReadWrite => 2,
        OpenMode::Append => 3,
    }
}

fn mode_from_u8(b: u8) -> Result<OpenMode> {
    Ok(match b {
        0 => OpenMode::Read,
        1 => OpenMode::Write,
        2 => OpenMode::ReadWrite,
        3 => OpenMode::Append,
        other => return Err(Error::Daemon(format!("bad open mode byte {other}"))),
    })
}

/// The wire order of [`MgmtCounters`]' fields. Count-prefixed on the
/// wire so a field appended in a later revision decodes as zero on an
/// older peer instead of desynchronizing the frame.
fn counters_to_fields(c: &MgmtCounters) -> Vec<u64> {
    vec![
        c.flushes,
        c.evictions,
        c.self_spills,
        c.victim_spills,
        c.promotions,
        c.prefetched,
        c.flush_bytes,
        c.spill_bytes,
        c.promote_bytes,
        c.prefetch_bytes,
        c.flush_physical_bytes,
        c.spill_physical_bytes,
        c.promote_physical_bytes,
        c.prefetch_physical_bytes,
        c.peak_copy_buffer_bytes,
        c.page_faults,
        c.page_hits,
        c.page_evictions,
        c.page_writeback_bytes,
        c.page_shared_hits,
        c.page_frames_deduped,
        c.page_resident_bytes,
        c.page_peak_resident_bytes,
    ]
}

fn counters_from_fields(f: &[u64]) -> MgmtCounters {
    let g = |i: usize| f.get(i).copied().unwrap_or(0);
    MgmtCounters {
        flushes: g(0),
        evictions: g(1),
        self_spills: g(2),
        victim_spills: g(3),
        promotions: g(4),
        prefetched: g(5),
        flush_bytes: g(6),
        spill_bytes: g(7),
        promote_bytes: g(8),
        prefetch_bytes: g(9),
        flush_physical_bytes: g(10),
        spill_physical_bytes: g(11),
        promote_physical_bytes: g(12),
        prefetch_physical_bytes: g(13),
        peak_copy_buffer_bytes: g(14),
        page_faults: g(15),
        page_hits: g(16),
        page_evictions: g(17),
        page_writeback_bytes: g(18),
        page_shared_hits: g(19),
        page_frames_deduped: g(20),
        page_resident_bytes: g(21),
        page_peak_resident_bytes: g(22),
    }
}

/// Encode an [`ObsSnapshot`] sparsely: per metric its index, the
/// count/sum/max gauges, and only the non-zero log₂ buckets as
/// `(bucket index, count)` pairs — an idle daemon's tail is a handful
/// of bytes, not 20 × 64 zeros.
fn put_obs(b: &mut Vec<u8>, s: &ObsSnapshot) {
    put_u32(b, s.metrics.len() as u32);
    for (idx, h) in &s.metrics {
        put_u8(b, *idx);
        put_u64(b, h.count);
        put_u64(b, h.sum);
        put_u64(b, h.max);
        let filled = h.buckets.iter().enumerate().filter(|(_, &c)| c > 0);
        put_u8(b, filled.clone().count() as u8);
        for (bi, &bc) in filled {
            put_u8(b, bi as u8);
            put_u64(b, bc);
        }
    }
}

fn get_obs(c: &mut Cur) -> Result<ObsSnapshot> {
    let n = c.u32()? as usize;
    if n > 256 {
        return Err(Error::Daemon(format!("oversized histogram list: {n}")));
    }
    let mut metrics = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = c.u8()?;
        let count = c.u64()?;
        let sum = c.u64()?;
        let max = c.u64()?;
        let nb = c.u8()? as usize;
        let mut buckets = [0u64; hist::BUCKETS];
        for _ in 0..nb {
            let bi = c.u8()? as usize;
            let bc = c.u64()?;
            if bi >= hist::BUCKETS {
                return Err(Error::Daemon(format!("histogram bucket {bi} out of range")));
            }
            buckets[bi] = bc;
        }
        metrics.push((idx, hist::HistSnapshot { buckets, count, sum, max }));
    }
    Ok(ObsSnapshot { metrics })
}

// --- request ---------------------------------------------------------------

impl Request {
    /// Serialize into a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(32);
        match self {
            Request::Hello { version } => {
                put_u8(&mut b, OP_HELLO);
                put_u32(&mut b, *version);
            }
            Request::Open { mode, path } => {
                put_u8(&mut b, OP_OPEN);
                put_u8(&mut b, mode_to_u8(*mode));
                put_str(&mut b, path);
            }
            Request::Pread { handle, off, len } => {
                put_u8(&mut b, OP_PREAD);
                put_u64(&mut b, *handle);
                put_u64(&mut b, *off);
                put_u32(&mut b, *len);
            }
            Request::Pwrite { handle, off, data } => {
                put_u8(&mut b, OP_PWRITE);
                put_u64(&mut b, *handle);
                put_u64(&mut b, *off);
                put_bytes(&mut b, data);
            }
            Request::SetLen { handle, len } => {
                put_u8(&mut b, OP_SET_LEN);
                put_u64(&mut b, *handle);
                put_u64(&mut b, *len);
            }
            Request::Fsync { handle } => {
                put_u8(&mut b, OP_FSYNC);
                put_u64(&mut b, *handle);
            }
            Request::Close { handle } => {
                put_u8(&mut b, OP_CLOSE);
                put_u64(&mut b, *handle);
            }
            Request::Len { handle } => {
                put_u8(&mut b, OP_LEN);
                put_u64(&mut b, *handle);
            }
            Request::Stat { path } => {
                put_u8(&mut b, OP_STAT);
                put_str(&mut b, path);
            }
            Request::Readdir { path, token } => {
                put_u8(&mut b, OP_READDIR);
                put_str(&mut b, path);
                put_u64(&mut b, *token);
            }
            Request::Mkdir { path } => {
                put_u8(&mut b, OP_MKDIR);
                put_str(&mut b, path);
            }
            Request::Rename { from, to } => {
                put_u8(&mut b, OP_RENAME);
                put_str(&mut b, from);
                put_str(&mut b, to);
            }
            Request::Unlink { path } => {
                put_u8(&mut b, OP_UNLINK);
                put_str(&mut b, path);
            }
            Request::MapSync { handle } => {
                put_u8(&mut b, OP_MAP_SYNC);
                put_u64(&mut b, *handle);
            }
            Request::NoteFault { handle, off, len } => {
                put_u8(&mut b, OP_NOTE_FAULT);
                put_u64(&mut b, *handle);
                put_u64(&mut b, *off);
                put_u64(&mut b, *len);
            }
            Request::Counters => put_u8(&mut b, OP_COUNTERS),
            Request::SyncMgmt => put_u8(&mut b, OP_SYNC_MGMT),
        }
        b
    }

    /// Parse a frame payload.
    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut c = Cur::new(buf);
        let op = c.u8()?;
        let req = match op {
            OP_HELLO => Request::Hello { version: c.u32()? },
            OP_OPEN => {
                let mode = mode_from_u8(c.u8()?)?;
                Request::Open { mode, path: c.str()? }
            }
            OP_PREAD => Request::Pread { handle: c.u64()?, off: c.u64()?, len: c.u32()? },
            OP_PWRITE => {
                Request::Pwrite { handle: c.u64()?, off: c.u64()?, data: c.bytes()? }
            }
            OP_SET_LEN => Request::SetLen { handle: c.u64()?, len: c.u64()? },
            OP_FSYNC => Request::Fsync { handle: c.u64()? },
            OP_CLOSE => Request::Close { handle: c.u64()? },
            OP_LEN => Request::Len { handle: c.u64()? },
            OP_STAT => Request::Stat { path: c.str()? },
            OP_READDIR => Request::Readdir { path: c.str()?, token: c.u64()? },
            OP_MKDIR => Request::Mkdir { path: c.str()? },
            OP_RENAME => Request::Rename { from: c.str()?, to: c.str()? },
            OP_UNLINK => Request::Unlink { path: c.str()? },
            OP_MAP_SYNC => Request::MapSync { handle: c.u64()? },
            OP_NOTE_FAULT => {
                Request::NoteFault { handle: c.u64()?, off: c.u64()?, len: c.u64()? }
            }
            OP_COUNTERS => Request::Counters,
            OP_SYNC_MGMT => Request::SyncMgmt,
            other => return Err(Error::Daemon(format!("unknown opcode {other:#x}"))),
        };
        c.done()?;
        Ok(req)
    }

    /// May this request be transparently retried on a fresh connection
    /// after a mid-request connection loss? Reads, probes, and
    /// `Mkdir` (whose `create_dir_all` semantics make a replay a
    /// no-op) — a lost mutating request may or may not have been
    /// applied, so it must surface [`Error::DaemonGone`] instead.
    pub fn idempotent(&self) -> bool {
        matches!(
            self,
            Request::Hello { .. }
                | Request::Pread { .. }
                | Request::Len { .. }
                | Request::Stat { .. }
                | Request::Readdir { .. }
                | Request::Mkdir { .. }
                | Request::MapSync { .. }
                | Request::NoteFault { .. }
                | Request::Counters
        )
    }
}

// --- response --------------------------------------------------------------

const BODY_UNIT: u8 = 0;
const BODY_HELLO: u8 = 1;
const BODY_OPEN: u8 = 2;
const BODY_DATA: u8 = 3;
const BODY_WRITTEN: u8 = 4;
const BODY_SIZE: u8 = 5;
const BODY_NAMES: u8 = 6;
const BODY_COUNTERS: u8 = 7;

impl Response {
    /// A success response.
    pub fn ok(gen: u64, body: Body) -> Response {
        Response { gen, body: Ok(body) }
    }

    /// An error response carrying a typed daemon-side failure.
    pub fn err(gen: u64, e: &Error) -> Response {
        Response { gen, body: Err(WireError::from_error(e)) }
    }

    /// An error response from an explicit wire code (protocol-level
    /// failures that never existed as a daemon [`Error`]).
    pub fn err_code(code: ErrCode, msg: impl Into<String>) -> Response {
        Response {
            gen: 0,
            body: Err(WireError {
                code,
                msg: msg.into(),
                path: String::new(),
                a: 0,
                b: 0,
            }),
        }
    }

    /// Serialize into a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(32);
        match &self.body {
            Ok(body) => {
                put_u8(&mut b, 0);
                put_u64(&mut b, self.gen);
                match body {
                    Body::Unit => put_u8(&mut b, BODY_UNIT),
                    Body::Hello { version, chunk_bytes } => {
                        put_u8(&mut b, BODY_HELLO);
                        put_u32(&mut b, *version);
                        put_u64(&mut b, *chunk_bytes);
                    }
                    Body::Open { handle, ident, lease } => {
                        put_u8(&mut b, BODY_OPEN);
                        put_u64(&mut b, *handle);
                        match ident {
                            Some(i) => {
                                put_u8(&mut b, 1);
                                put_u128(&mut b, *i);
                            }
                            None => put_u8(&mut b, 0),
                        }
                        match lease {
                            Some(g) => {
                                put_u8(&mut b, 1);
                                put_u64(&mut b, *g);
                            }
                            None => put_u8(&mut b, 0),
                        }
                    }
                    Body::Data(d) => {
                        put_u8(&mut b, BODY_DATA);
                        put_bytes(&mut b, d);
                    }
                    Body::Written(n) => {
                        put_u8(&mut b, BODY_WRITTEN);
                        put_u32(&mut b, *n);
                    }
                    Body::Size(n) => {
                        put_u8(&mut b, BODY_SIZE);
                        put_u64(&mut b, *n);
                    }
                    Body::Names { names, next } => {
                        put_u8(&mut b, BODY_NAMES);
                        put_u32(&mut b, names.len() as u32);
                        for n in names {
                            put_str(&mut b, n);
                        }
                        put_u64(&mut b, *next);
                    }
                    Body::Counters(c) => {
                        put_u8(&mut b, BODY_COUNTERS);
                        put_str(&mut b, &c.engine);
                        put_u32(&mut b, c.ledger.len() as u32);
                        for l in &c.ledger {
                            put_str(&mut b, &l.name);
                            put_u8(&mut b, l.tier);
                            put_u64(&mut b, l.capacity);
                            put_u64(&mut b, l.free);
                            put_u64(&mut b, l.used);
                            put_u64(&mut b, l.debits);
                            put_u64(&mut b, l.credits);
                            put_u64(&mut b, l.logical);
                        }
                        let fields = counters_to_fields(&c.counters);
                        put_u32(&mut b, fields.len() as u32);
                        for f in fields {
                            put_u64(&mut b, f);
                        }
                        put_u64(&mut b, c.clients_connected);
                        put_u64(&mut b, c.clients_total);
                        put_u64(&mut b, c.open_handles);
                        put_u64(&mut b, c.ops_served);
                        put_u64(&mut b, c.leases_granted);
                        put_u64(&mut b, c.inflight_peak);
                        // v3 tail: present only when the daemon chose
                        // to attach histograms (it sets `lat: None` on
                        // v2 connections, keeping their frames v2)
                        if let Some(lat) = &c.lat {
                            put_obs(&mut b, lat);
                        }
                    }
                }
            }
            Err(we) => {
                put_u8(&mut b, 1);
                put_u64(&mut b, self.gen);
                put_u8(&mut b, we.code as u8);
                put_str(&mut b, &we.msg);
                put_str(&mut b, &we.path);
                put_u64(&mut b, we.a);
                put_u64(&mut b, we.b);
            }
        }
        b
    }

    /// Parse a frame payload.
    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut c = Cur::new(buf);
        let status = c.u8()?;
        let gen = c.u64()?;
        if status == 1 {
            let code = ErrCode::from_u8(c.u8()?);
            let msg = c.str()?;
            let path = c.str()?;
            let a = c.u64()?;
            let b = c.u64()?;
            c.done()?;
            return Ok(Response { gen, body: Err(WireError { code, msg, path, a, b }) });
        }
        let tag = c.u8()?;
        let body = match tag {
            BODY_UNIT => Body::Unit,
            BODY_HELLO => Body::Hello { version: c.u32()?, chunk_bytes: c.u64()? },
            BODY_OPEN => {
                let handle = c.u64()?;
                let ident = match c.u8()? {
                    0 => None,
                    _ => Some(c.u128()?),
                };
                let lease = match c.u8()? {
                    0 => None,
                    _ => Some(c.u64()?),
                };
                Body::Open { handle, ident, lease }
            }
            BODY_DATA => Body::Data(c.bytes()?),
            BODY_WRITTEN => Body::Written(c.u32()?),
            BODY_SIZE => Body::Size(c.u64()?),
            BODY_NAMES => {
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 4 {
                    return Err(Error::Daemon(format!("oversized name list: {n}")));
                }
                let mut names = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    names.push(c.str()?);
                }
                Body::Names { names, next: c.u64()? }
            }
            BODY_COUNTERS => {
                let engine = c.str()?;
                let nl = c.u32()? as usize;
                if nl > 4096 {
                    return Err(Error::Daemon(format!("oversized ledger: {nl}")));
                }
                let mut ledger = Vec::with_capacity(nl);
                for _ in 0..nl {
                    ledger.push(DeviceLedger {
                        name: c.str()?,
                        tier: c.u8()?,
                        capacity: c.u64()?,
                        free: c.u64()?,
                        used: c.u64()?,
                        debits: c.u64()?,
                        credits: c.u64()?,
                        logical: c.u64()?,
                    });
                }
                let nf = c.u32()? as usize;
                if nf > 1024 {
                    return Err(Error::Daemon(format!("oversized counter list: {nf}")));
                }
                let mut fields = Vec::with_capacity(nf);
                for _ in 0..nf {
                    fields.push(c.u64()?);
                }
                let clients_connected = c.u64()?;
                let clients_total = c.u64()?;
                let open_handles = c.u64()?;
                let ops_served = c.u64()?;
                let leases_granted = c.u64()?;
                let inflight_peak = c.u64()?;
                // v3 histogram tail: a v2 peer's frame simply ends
                // here, which decodes as "no histograms"
                let lat = if c.remaining() > 0 { Some(get_obs(&mut c)?) } else { None };
                Body::Counters(Box::new(CountersReply {
                    engine,
                    ledger,
                    counters: counters_from_fields(&fields),
                    clients_connected,
                    clients_total,
                    open_handles,
                    ops_served,
                    leases_granted,
                    inflight_peak,
                    lat,
                }))
            }
            other => return Err(Error::Daemon(format!("unknown body tag {other}"))),
        };
        c.done()?;
        Ok(Response { gen, body: Ok(body) })
    }
}

// --- frame I/O -------------------------------------------------------------

/// Bytes of frame header preceding the payload: `[u32 len][u64 id]`.
pub const FRAME_HDR: usize = 12;

/// Encode the 12-byte frame header for a payload of `len` bytes.
pub fn frame_header(id: u64, len: usize) -> [u8; FRAME_HDR] {
    let mut hdr = [0u8; FRAME_HDR];
    hdr[..4].copy_from_slice(&(len as u32).to_le_bytes());
    hdr[4..].copy_from_slice(&id.to_le_bytes());
    hdr
}

/// Write one id-bearing frame **vectored**: header and payload go out
/// in a single `writev` when the writer supports it, so the payload is
/// never copied into a staging buffer.
pub fn write_frame(w: &mut impl Write, id: u64, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let hdr = frame_header(id, payload.len());
    let total = FRAME_HDR + payload.len();
    let mut sent = 0usize;
    while sent < total {
        let bufs = if sent < FRAME_HDR {
            [std::io::IoSlice::new(&hdr[sent..]), std::io::IoSlice::new(payload)]
        } else {
            [
                std::io::IoSlice::new(&payload[sent - FRAME_HDR..]),
                std::io::IoSlice::new(&[]),
            ]
        };
        match w.write_vectored(&bufs) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "frame write returned zero",
                ))
            }
            Ok(n) => sent += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    w.flush()
}

/// Read one id-bearing frame, returning `(id, payload)`. An EOF before
/// the first header byte returns `UnexpectedEof` with an empty message
/// (clean close); any other short read is a protocol error.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<(u64, Vec<u8>)> {
    let mut hdr = [0u8; FRAME_HDR];
    r.read_exact(&mut hdr)?;
    let n = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
    let id = u64::from_le_bytes(hdr[4..].try_into().unwrap());
    if n > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {n} bytes exceeds MAX_FRAME"),
        ));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok((id, buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt_req(r: Request) {
        let enc = r.encode();
        assert_eq!(Request::decode(&enc).unwrap(), r, "request round-trip");
    }

    fn rt_resp(r: Response) {
        let enc = r.encode();
        assert_eq!(Response::decode(&enc).unwrap(), r, "response round-trip");
    }

    #[test]
    fn requests_round_trip() {
        rt_req(Request::Hello { version: PROTOCOL_VERSION });
        rt_req(Request::Open { mode: OpenMode::Append, path: "/sea/a/b.dat".into() });
        rt_req(Request::Pread { handle: 7, off: 1 << 40, len: 4096 });
        rt_req(Request::Pwrite { handle: 7, off: 0, data: vec![1, 2, 3] });
        rt_req(Request::Pwrite { handle: 1, off: 9, data: Vec::new() });
        rt_req(Request::SetLen { handle: 3, len: 12 });
        rt_req(Request::Fsync { handle: 3 });
        rt_req(Request::Close { handle: u64::MAX });
        rt_req(Request::Len { handle: 9 });
        rt_req(Request::Stat { path: "/sea/x".into() });
        rt_req(Request::Readdir { path: "/sea".into(), token: 0 });
        rt_req(Request::Readdir { path: "/sea".into(), token: 4096 });
        rt_req(Request::Rename { from: "/sea/a".into(), to: "/sea/b".into() });
        rt_req(Request::Unlink { path: "/sea/a".into() });
        rt_req(Request::Mkdir { path: "/sea/out/run7".into() });
        rt_req(Request::MapSync { handle: 2 });
        rt_req(Request::NoteFault { handle: 2, off: 64, len: 4096 });
        rt_req(Request::Counters);
        rt_req(Request::SyncMgmt);
    }

    #[test]
    fn responses_round_trip() {
        rt_resp(Response::ok(0, Body::Unit));
        rt_resp(Response::ok(3, Body::Hello { version: 2, chunk_bytes: 1 << 20 }));
        rt_resp(Response::ok(
            9,
            Body::Open { handle: 4, ident: Some(1 << 90), lease: Some(17) },
        ));
        rt_resp(Response::ok(9, Body::Open { handle: 4, ident: None, lease: None }));
        rt_resp(Response::ok(1, Body::Data(vec![0xAB; 100])));
        rt_resp(Response::ok(1, Body::Written(77)));
        rt_resp(Response::ok(0, Body::Size(u64::MAX / 3)));
        rt_resp(Response::ok(
            0,
            Body::Names { names: vec!["a.dat".into(), "b".into()], next: 0 },
        ));
        rt_resp(Response::ok(
            0,
            Body::Names { names: vec!["page1".into()], next: 2048 },
        ));
        rt_resp(Response::err_code(ErrCode::VersionMismatch, "daemon speaks 2"));
    }

    #[test]
    fn counters_round_trip() {
        let reply = CountersReply {
            engine: "temperature".into(),
            ledger: vec![DeviceLedger {
                name: "/dev/shm/t0".into(),
                tier: 0,
                capacity: 100,
                free: 40,
                used: 60,
                debits: 80,
                credits: 20,
                logical: 90,
            }],
            counters: MgmtCounters {
                flushes: 1,
                self_spills: 2,
                page_peak_resident_bytes: 1 << 33,
                ..Default::default()
            },
            clients_connected: 3,
            clients_total: 11,
            open_handles: 5,
            ops_served: 400,
            leases_granted: 6,
            inflight_peak: 4,
            lat: None,
        };
        let r = Response::ok(0, Body::Counters(Box::new(reply.clone())));
        let dec = Response::decode(&r.encode()).unwrap();
        match dec.body.unwrap() {
            Body::Counters(c) => {
                assert_eq!(*c, reply);
                assert_eq!(c.counters.self_spills, 2);
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    fn sample_snapshot() -> ObsSnapshot {
        let h = hist::Hist::new();
        for v in [120u64, 900, 15_000, 15_001, 2_000_000] {
            h.record(v);
        }
        ObsSnapshot { metrics: vec![(0, h.snapshot()), (19, h.snapshot())] }
    }

    #[test]
    fn counters_with_histograms_round_trip_sparsely() {
        let reply = CountersReply {
            engine: "paper".into(),
            ledger: Vec::new(),
            counters: MgmtCounters::default(),
            clients_connected: 1,
            clients_total: 1,
            open_handles: 0,
            ops_served: 9,
            leases_granted: 0,
            inflight_peak: 1,
            lat: Some(sample_snapshot()),
        };
        let enc = Response::ok(0, Body::Counters(Box::new(reply.clone()))).encode();
        // sparse: two metrics × (1 + 24 + 1 + 4 non-zero buckets × 9)
        // plus the u32 metric count — nowhere near 20 × 64 × 8
        let no_lat = Response::ok(
            0,
            Body::Counters(Box::new(CountersReply { lat: None, ..reply.clone() })),
        )
        .encode();
        assert!(enc.len() - no_lat.len() < 200, "tail is {}", enc.len() - no_lat.len());
        let dec = Response::decode(&enc).unwrap();
        match dec.body.unwrap() {
            Body::Counters(c) => {
                assert_eq!(*c, reply);
                let lat = c.lat.unwrap();
                assert_eq!(lat.metrics.len(), 2);
                assert_eq!(lat.metrics[0].1.count, 5);
                assert_eq!(lat.metrics[0].1.max, 2_000_000);
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn v2_counters_frame_decodes_on_a_v3_client() {
        // A v2 daemon's Counters frame is byte-identical to a v3 frame
        // with no histogram tail: encoding with `lat: None` *is* the
        // v2 layout. Both directions of the compat contract hold —
        // the old frame decodes (lat == None, nothing lost), and the
        // new client's `sea stat --connect` degrades to counters-only.
        let v2 = CountersReply {
            engine: "temperature".into(),
            ledger: Vec::new(),
            counters: MgmtCounters { flushes: 7, ..Default::default() },
            clients_connected: 2,
            clients_total: 2,
            open_handles: 1,
            ops_served: 50,
            leases_granted: 1,
            inflight_peak: 2,
            lat: None,
        };
        let frame = Response::ok(0, Body::Counters(Box::new(v2.clone()))).encode();
        let dec = Response::decode(&frame).unwrap();
        match dec.body.unwrap() {
            Body::Counters(c) => {
                assert_eq!(c.counters.flushes, 7);
                assert!(c.lat.is_none(), "absent tail must decode as None");
            }
            other => panic!("wrong body: {other:?}"),
        }
        // and a malformed (truncated) tail is a typed error, not a panic
        let mut bad = frame;
        bad.extend_from_slice(&3u32.to_le_bytes()); // claims 3 histograms, has none
        assert!(Response::decode(&bad).is_err());
    }

    #[test]
    fn typed_errors_survive_the_wire() {
        let e = Error::NotFound(PathBuf::from("/sea/missing"));
        let r = Response::err(0, &e);
        let dec = Response::decode(&r.encode()).unwrap();
        match dec.body.unwrap_err().into_error() {
            Error::NotFound(p) => assert_eq!(p, PathBuf::from("/sea/missing")),
            other => panic!("wrong error: {other}"),
        }
        let e = Error::NoSpace { path: "/sea/f".into(), needed: 9, largest_free: 4 };
        let dec = Response::decode(&Response::err(0, &e).encode()).unwrap();
        match dec.body.unwrap_err().into_error() {
            Error::NoSpace { needed, largest_free, .. } => {
                assert_eq!((needed, largest_free), (9, 4));
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn garbage_frames_are_typed_errors_not_panics() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[0xFF]).is_err());
        assert!(Request::decode(&[OP_PREAD, 1, 2]).is_err(), "truncated operands");
        // trailing garbage is rejected, not silently ignored
        let mut enc = Request::Fsync { handle: 1 }.encode();
        enc.push(0);
        assert!(Request::decode(&enc).is_err());
        assert!(Response::decode(&[0]).is_err());
        // oversized embedded string length
        let mut b = vec![OP_STAT];
        b.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Request::decode(&b).is_err());
    }

    #[test]
    fn frame_io_round_trips_and_caps() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 42, b"hello").unwrap();
        let mut rd = &buf[..];
        let (id, payload) = read_frame(&mut rd).unwrap();
        assert_eq!(id, 42, "request id survives the header");
        assert_eq!(payload, b"hello");
        // an oversized header is refused before allocating
        let mut bad = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        bad.extend_from_slice(&[0; 16]);
        let mut rd = &bad[..];
        assert!(read_frame(&mut rd).is_err());
    }

    #[test]
    fn interleaved_frames_keep_their_ids() {
        // The pipelining contract: ids written in one order can be
        // consumed in any order because each frame carries its own.
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"first").unwrap();
        write_frame(&mut buf, 9, b"second").unwrap();
        let mut rd = &buf[..];
        assert_eq!(read_frame(&mut rd).unwrap(), (1, b"first".to_vec()));
        assert_eq!(read_frame(&mut rd).unwrap(), (9, b"second".to_vec()));
    }

    #[test]
    fn idempotence_classification() {
        assert!(Request::Pread { handle: 1, off: 0, len: 1 }.idempotent());
        assert!(Request::Stat { path: "x".into() }.idempotent());
        assert!(Request::MapSync { handle: 1 }.idempotent());
        assert!(Request::Mkdir { path: "x".into() }.idempotent());
        assert!(Request::Readdir { path: "x".into(), token: 7 }.idempotent());
        assert!(!Request::Pwrite { handle: 1, off: 0, data: vec![] }.idempotent());
        assert!(!Request::SetLen { handle: 1, len: 0 }.idempotent());
        assert!(!Request::Unlink { path: "x".into() }.idempotent());
        assert!(!Request::Rename { from: "x".into(), to: "y".into() }.idempotent());
        assert!(!Request::SyncMgmt.idempotent());
    }
}
