//! **Sea as a service**: the `sea serve` daemon.
//!
//! Everything else in this crate is one process owning one mount. This
//! module turns that mount into a shared service: a daemon owns the
//! [`SeaFs`] — one placement brain, one ledger, one page budget — and
//! any number of client processes (a [`crate::vfs::remote::RemoteFs`],
//! or unmodified binaries through the `sea-interpose` shim with
//! `SEA_SOCKET` set) speak a compact binary protocol to it over a Unix
//! domain socket. Because every append from every client resolves its
//! offset behind the daemon's registry shard lock, concurrent appenders
//! in *different processes* never interleave records — closing the
//! stripe-mode `OpenMode::Append` cross-process atomicity gap — and the
//! heat map the placement engine sees is the cluster's access pattern,
//! not one process's.
//!
//! ## Wire format
//!
//! See [`protocol`] for the full encoding. The short version:
//!
//! | frame    | layout                                                |
//! |----------|-------------------------------------------------------|
//! | any      | `[u32 len][u64 req-id][payload…]`, little-endian, `len <=` [`protocol::MAX_FRAME`] |
//! | request  | `[opcode u8][operands…]`                              |
//! | response | `[status u8][gen u64][body…]`, echoing the request id |
//!
//! The `gen` slot of every response carries the daemon-side map
//! generation of the touched handle: one client's spill propagates to
//! every other client on their next response, and they invalidate
//! their emulated mappings — cross-process page coherence without a
//! broadcast channel.
//!
//! ## Control plane vs data plane
//!
//! Since the request id lets responses travel out of order, each
//! connection runs a small executor ([`CONN_WORKERS`] threads):
//! independent requests from one client no longer serialize behind
//! each other — only ops on the *same handle* do, behind that handle's
//! lock. And for read-only opens whose resident replica sits on a
//! local `RealFs`-backed device, the daemon leases a dup'd `O_RDONLY`
//! fd to the client over `SCM_RIGHTS` ([`fdpass`]): the client then
//! preads the file directly — zero round trips, zero wire copies —
//! until a piggybacked generation bump revokes the lease. Spills and
//! rename-over unlink the old inode but never truncate it, so a
//! revoked-but-in-flight read still returns a consistent snapshot.
//!
//! ## Lifecycle
//!
//! [`Server::spawn`] claims the socket (probing for a live daemon
//! before unlinking a stale file, then binding with `0600`
//! permissions), and serves thread-per-connection. Each connection
//! gets a version handshake, a private handle table, and an idle
//! deadline ([`ServeCfg::idle_timeout`]) — a client silent for that
//! long between frames is reaped (its handles drop, running any
//! deferred Sea management). [`Server::shutdown`] drains: no new
//! connections, in-flight requests finish and are answered, handle
//! tables drop (closing writer handles), threads join, the socket file
//! is removed.

pub mod fdpass;
pub mod protocol;

use std::collections::HashMap;
use std::io::ErrorKind;
use std::os::fd::AsRawFd;
use std::os::unix::fs::PermissionsExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs::{self, trace};
use crate::vfs::sea::SeaFs;
use crate::vfs::{OpenMode, Vfs, VfsFile};
use protocol::{
    read_frame, write_frame, Body, CountersReply, ErrCode, Request, Response,
    MIN_PROTOCOL_VERSION, PROTOCOL_VERSION,
};

/// How often a connection thread wakes to check the shutdown flag and
/// its idle deadline while waiting for the next frame.
const POLL_TICK: Duration = Duration::from_millis(25);

/// Worker threads per connection: how many of one client's requests
/// may execute concurrently. Small on purpose — enough to overlap a
/// slow pread with metadata ops, without letting a single client
/// monopolize the daemon.
pub const CONN_WORKERS: usize = 4;

/// Encoded-bytes budget of one `Readdir` reply page; keeps listing
/// frames far under [`protocol::MAX_IO`] no matter how wide the
/// directory is.
const READDIR_PAGE_BYTES: usize = 256 * 1024;

/// Readahead hint advertised in the `Hello` reply when the served Vfs
/// is not a Sea mount (no `chunk_bytes` tuning to forward).
const DEFAULT_CHUNK_HINT: u64 = 1 << 20;

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Unix-domain socket path to listen on.
    pub socket: PathBuf,
    /// Reap a client silent for this long between frames. Generous by
    /// default — a reaped read-only client transparently reconnects.
    pub idle_timeout: Duration,
    /// Lease dup'd `O_RDONLY` fds to read-only clients over
    /// `SCM_RIGHTS` when the resident replica supports it (see
    /// [`crate::vfs::VfsFile::lease_fd`]). On by default; `sea serve
    /// --no-leases` turns it off.
    pub lease_fds: bool,
}

impl ServeCfg {
    /// Defaults: 5-minute idle reaping, fd leases on.
    pub fn new(socket: impl Into<PathBuf>) -> ServeCfg {
        ServeCfg {
            socket: socket.into(),
            idle_timeout: Duration::from_secs(300),
            lease_fds: true,
        }
    }
}

/// Live service gauges (the `clients:` line of `sea stat --connect`).
#[derive(Debug, Default)]
struct Gauges {
    clients_connected: AtomicU64,
    clients_total: AtomicU64,
    open_handles: AtomicU64,
    ops_served: AtomicU64,
    leases_granted: AtomicU64,
    inflight_peak: AtomicU64,
}

struct Shared {
    fs: Arc<dyn Vfs>,
    /// The concrete Sea mount when the served Vfs is one (counters,
    /// ledger, engine name for the `Counters` reply).
    sea: Option<Arc<SeaFs>>,
    shutdown: AtomicBool,
    idle_timeout: Duration,
    lease_fds: bool,
    /// `chunk_bytes` forwarded to clients in the `Hello` reply as
    /// their default readahead window.
    chunk_hint: u64,
    gauges: Gauges,
}

/// A running `sea serve` daemon (in-process handle).
///
/// Dropping the server *without* calling [`Server::shutdown`] still
/// shuts it down, but abruptly-ish: the flag is set and threads are
/// joined, identical to `shutdown` minus the error reporting.
pub struct Server {
    shared: Arc<Shared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conn_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    socket: PathBuf,
}

impl Server {
    /// Claim `cfg.socket` and start serving `sea` on it.
    pub fn spawn(sea: Arc<SeaFs>, cfg: ServeCfg) -> Result<Server> {
        Server::spawn_vfs(sea.clone() as Arc<dyn Vfs>, Some(sea), cfg)
    }

    /// Serve an arbitrary [`Vfs`] (tests, decorated mounts). The
    /// `Counters` reply degrades gracefully when `sea` is `None`.
    pub fn spawn_vfs(
        fs: Arc<dyn Vfs>,
        sea: Option<Arc<SeaFs>>,
        cfg: ServeCfg,
    ) -> Result<Server> {
        let listener = claim_socket(&cfg.socket)?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io(cfg.socket.clone(), e))?;
        let chunk_hint = sea
            .as_ref()
            .map(|s| s.chunk_bytes() as u64)
            .unwrap_or(DEFAULT_CHUNK_HINT);
        let shared = Arc::new(Shared {
            fs,
            sea,
            shutdown: AtomicBool::new(false),
            idle_timeout: cfg.idle_timeout,
            lease_fds: cfg.lease_fds,
            chunk_hint,
            gauges: Gauges::default(),
        });
        let conn_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept_shared = shared.clone();
        let accept_conns = conn_threads.clone();
        let accept_thread = std::thread::Builder::new()
            .name("sea-serve-accept".into())
            .spawn(move || accept_loop(listener, accept_shared, accept_conns))
            .map_err(|e| Error::io(cfg.socket.clone(), e))?;
        Ok(Server {
            shared,
            accept_thread: Some(accept_thread),
            conn_threads,
            socket: cfg.socket,
        })
    }

    /// The socket this daemon listens on.
    pub fn socket_path(&self) -> &Path {
        &self.socket
    }

    /// Has a shutdown been requested (e.g. by a signal handler)?
    pub fn shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Request + complete a graceful shutdown: stop accepting, let
    /// every in-flight request finish and be answered, drop all handle
    /// tables (running deferred Sea management for writer handles),
    /// join all threads, remove the socket file.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_and_join();
        Ok(())
    }

    fn stop_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let threads: Vec<_> = {
            let mut g = self.conn_threads.lock().unwrap();
            g.drain(..).collect()
        };
        for t in threads {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.socket);
        // Writers are closed; give the mount a chance to drain the
        // management those closes queued.
        if let Some(sea) = &self.shared.sea {
            let _ = sea.sync_mgmt();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Bind `socket`, removing a stale file first — but only after probing
/// that no live daemon answers on it (a successful connect means one
/// does, and we refuse to steal its socket). The bound socket gets
/// `0600` permissions: the placement brain takes orders only from the
/// owning user.
fn claim_socket(socket: &Path) -> Result<UnixListener> {
    if socket.exists() {
        match UnixStream::connect(socket) {
            Ok(_) => {
                return Err(Error::Daemon(format!(
                    "a live daemon already serves {}",
                    socket.display()
                )));
            }
            Err(_) => {
                // Nobody home: a stale socket from an unclean exit.
                std::fs::remove_file(socket)
                    .map_err(|e| Error::io(socket.to_path_buf(), e))?;
            }
        }
    }
    if let Some(parent) = socket.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| Error::io(socket.to_path_buf(), e))?;
        }
    }
    let listener =
        UnixListener::bind(socket).map_err(|e| Error::io(socket.to_path_buf(), e))?;
    std::fs::set_permissions(socket, std::fs::Permissions::from_mode(0o600))
        .map_err(|e| Error::io(socket.to_path_buf(), e))?;
    Ok(listener)
}

fn accept_loop(
    listener: UnixListener,
    shared: Arc<Shared>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                shared.gauges.clients_total.fetch_add(1, Ordering::Relaxed);
                shared.gauges.clients_connected.fetch_add(1, Ordering::Relaxed);
                let conn_shared = shared.clone();
                if let Ok(t) = std::thread::Builder::new()
                    .name("sea-serve-conn".into())
                    .spawn(move || {
                        serve_connection(stream, &conn_shared);
                        conn_shared
                            .gauges
                            .clients_connected
                            .fetch_sub(1, Ordering::Relaxed);
                    })
                {
                    conns.lock().unwrap().push(t);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_TICK);
            }
            Err(_) => break,
        }
    }
}

/// One open handle in a connection's table.
struct Handle {
    file: Box<dyn VfsFile>,
}

/// Per-connection executor state, shared by the frame-reader loop and
/// the [`CONN_WORKERS`] op workers.
struct ConnState {
    shared: Arc<Shared>,
    /// Every response frame (and any leased fd riding it) leaves
    /// through here; the lock spans one whole vectored write, keeping
    /// concurrently-finishing responses from interleaving.
    writer: Mutex<UnixStream>,
    /// Handle table. Ops on the *same* handle serialize behind its
    /// `Mutex`; different handles proceed concurrently. `Close`
    /// removes the entry while an in-flight op keeps its own `Arc`
    /// clone alive until it finishes.
    handles: Mutex<HashMap<u64, Arc<Mutex<Handle>>>>,
    next_handle: AtomicU64,
    /// Requests executing right now (feeds the `inflight_peak` gauge).
    inflight: AtomicU64,
    /// Protocol revision negotiated at handshake — the client's, which
    /// the daemon serves verbatim. Gates reply fields newer clients
    /// understand (the v3 `Counters` histogram tail).
    version: u32,
}

/// Wait for the next frame, polling so the shutdown flag and the idle
/// deadline are honored *between* frames only — once the first header
/// byte of a frame has arrived, the read commits until the frame
/// completes (an idle cut mid-frame would desynchronize the stream).
/// Returns `Ok(None)` on clean EOF, idle reap, or shutdown.
fn next_frame(
    stream: &mut UnixStream,
    shared: &Shared,
) -> std::io::Result<Option<(u64, Vec<u8>)>> {
    let idle_deadline = Instant::now() + shared.idle_timeout;
    stream.set_read_timeout(Some(POLL_TICK))?;
    let mut first = [0u8; 1];
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match std::io::Read::read(stream, &mut first) {
            Ok(0) => return Ok(None), // clean EOF between frames
            Ok(_) => break,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if Instant::now() >= idle_deadline {
                    return Ok(None); // idle reap
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    // Frame committed: finish it without an idle cut. Keep the short
    // read timeout (so a wedged peer cannot pin the thread forever past
    // shutdown) but retry timeouts until the frame completes.
    let mut hdr = [0u8; protocol::FRAME_HDR];
    hdr[0] = first[0];
    read_full(stream, &mut hdr[1..])?;
    let n = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
    let id = u64::from_le_bytes(hdr[4..].try_into().unwrap());
    if n > protocol::MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame of {n} bytes exceeds MAX_FRAME"),
        ));
    }
    let mut buf = vec![0u8; n];
    read_full(stream, &mut buf)?;
    Ok(Some((id, buf)))
}

/// `read_exact` that rides over the polling read timeout.
fn read_full(stream: &mut UnixStream, mut buf: &mut [u8]) -> std::io::Result<()> {
    while !buf.is_empty() {
        match std::io::Read::read(stream, buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "EOF mid-frame",
                ))
            }
            Ok(k) => buf = &mut buf[k..],
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn serve_connection(mut stream: UnixStream, shared: &Arc<Shared>) {
    // Handshake: the first frame must be a matching Hello. The reply
    // echoes the client's id (0 by convention) and advertises the
    // mount's chunk size as the readahead hint.
    let conn_version = match next_frame(&mut stream, shared) {
        Ok(Some((id, frame))) => match Request::decode(&frame) {
            Ok(Request::Hello { version })
                if (MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) =>
            {
                // serve the connection at the client's revision and
                // echo it back, so both sides agree on the frame shapes
                let resp = Response::ok(
                    0,
                    Body::Hello { version, chunk_bytes: shared.chunk_hint },
                );
                if write_frame(&mut stream, id, &resp.encode()).is_err() {
                    return;
                }
                version
            }
            Ok(Request::Hello { version }) => {
                let resp = Response::err_code(
                    ErrCode::VersionMismatch,
                    format!(
                        "daemon speaks protocol {MIN_PROTOCOL_VERSION}..={PROTOCOL_VERSION}, \
                         client sent {version}"
                    ),
                );
                let _ = write_frame(&mut stream, id, &resp.encode());
                return;
            }
            Ok(other) => {
                let resp = Response::err_code(
                    ErrCode::Other,
                    format!("expected Hello as first frame, got {other:?}"),
                );
                let _ = write_frame(&mut stream, id, &resp.encode());
                return;
            }
            Err(e) => {
                let resp = Response::err_code(ErrCode::Other, e.to_string());
                let _ = write_frame(&mut stream, id, &resp.encode());
                return;
            }
        },
        _ => return,
    };

    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let conn = Arc::new(ConnState {
        shared: shared.clone(),
        writer: Mutex::new(writer),
        handles: Mutex::new(HashMap::new()),
        next_handle: AtomicU64::new(1),
        inflight: AtomicU64::new(0),
        version: conn_version,
    });

    // The per-connection executor: the frame loop feeds decoded
    // requests to a small worker pool so independent ops overlap.
    let (tx, rx) = mpsc::channel::<(u64, Request)>();
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(CONN_WORKERS);
    for w in 0..CONN_WORKERS {
        let conn = conn.clone();
        let rx = rx.clone();
        if let Ok(t) = std::thread::Builder::new()
            .name(format!("sea-serve-op-{w}"))
            .spawn(move || loop {
                let job = rx.lock().unwrap().recv();
                match job {
                    Ok((id, req)) => execute(&conn, id, req),
                    Err(_) => break, // sender dropped: connection done
                }
            })
        {
            workers.push(t);
        }
    }

    loop {
        let (id, frame) = match next_frame(&mut stream, shared) {
            Ok(Some(f)) => f,
            _ => break,
        };
        let req = match Request::decode(&frame) {
            Ok(r) => r,
            Err(e) => {
                // Protocol desync: answer once, then drop the peer.
                respond(&conn, id, Response::err_code(ErrCode::Other, e.to_string()), None);
                break;
            }
        };
        shared.gauges.ops_served.fetch_add(1, Ordering::Relaxed);
        if tx.send((id, req)).is_err() {
            break;
        }
    }

    // Drain: close the queue, let workers finish (and answer) every
    // in-flight request, then drop the handle table — writer closes
    // run deferred Sea management — and finally the stream.
    drop(tx);
    for t in workers {
        let _ = t.join();
    }
    let n = {
        let mut g = conn.handles.lock().unwrap();
        let n = g.len() as u64;
        g.clear();
        n
    };
    shared.gauges.open_handles.fetch_sub(n, Ordering::Relaxed);
}

/// Run one request on a worker and send its response (plus any leased
/// fd riding the same sendmsg).
fn execute(conn: &ConnState, id: u64, req: Request) {
    let now = conn.inflight.fetch_add(1, Ordering::Relaxed) + 1;
    conn.shared.gauges.inflight_peak.fetch_max(now, Ordering::Relaxed);
    // per-request service time: decode already done, reply queued on
    // the writer before the timer stops
    let t = obs::Timer::start();
    let (resp, lease) = handle_request(req, conn);
    respond(conn, id, resp, lease);
    t.stop(obs::Metric::DaemonRequest);
    conn.inflight.fetch_sub(1, Ordering::Relaxed);
}

/// Serialize and send one response frame. A write failure is not
/// reported here — the frame loop notices the dead peer on its next
/// read and tears the connection down.
fn respond(conn: &ConnState, id: u64, resp: Response, lease: Option<std::fs::File>) {
    let payload = resp.encode();
    let w = conn.writer.lock().unwrap();
    match lease {
        Some(f) => {
            // The fd must ride the exact frame that announces it, in
            // one sendmsg: stream order is the association.
            let hdr = protocol::frame_header(id, payload.len());
            let _ = fdpass::send_frame_fd(
                w.as_raw_fd(),
                &[&hdr, &payload],
                Some(f.as_raw_fd()),
            );
            // `f` drops here; the copy in flight keeps the open file
            // description alive on its own.
        }
        None => {
            let mut w = &*w;
            let _ = write_frame(&mut w, id, &payload);
        }
    }
}

fn handle_request(req: Request, conn: &ConnState) -> (Response, Option<std::fs::File>) {
    let shared = &*conn.shared;

    /// Piggybacked generation of a handle after an op (0 when the
    /// registry lookup itself fails — the op's own error wins).
    fn gen_of(h: &mut Handle) -> u64 {
        h.file.map_sync().unwrap_or(0)
    }

    macro_rules! with_handle {
        ($id:expr, |$h:ident| $body:expr) => {{
            let slot = conn.handles.lock().unwrap().get(&$id).cloned();
            match slot {
                Some(slot) => {
                    let mut guard = slot.lock().unwrap();
                    let $h = &mut *guard;
                    $body
                }
                None => Response::err_code(ErrCode::BadHandle, format!("handle {}", $id)),
            }
        }};
    }

    let resp = match req {
        Request::Hello { .. } => Response::ok(
            0,
            Body::Hello { version: PROTOCOL_VERSION, chunk_bytes: shared.chunk_hint },
        ),
        Request::Open { mode, path } => {
            if shared.shutdown.load(Ordering::SeqCst) && mode.writable() {
                return (Response::err_code(ErrCode::Shutdown, "no new writers"), None);
            }
            match shared.fs.open(Path::new(&path), mode) {
                Ok(file) => {
                    let id = conn.next_handle.fetch_add(1, Ordering::Relaxed);
                    let mut h = Handle { file };
                    let ident = h.file.map_identity();
                    let gen = gen_of(&mut h);
                    // Data plane: a read-only open whose replica can
                    // surface a raw fd gets it dup'd and leased at the
                    // current generation.
                    let lease = if mode == OpenMode::Read && shared.lease_fds {
                        h.file.lease_fd()
                    } else {
                        None
                    };
                    if lease.is_some() {
                        shared.gauges.leases_granted.fetch_add(1, Ordering::Relaxed);
                        trace::instant("lease-grant", "daemon", "read-open", 0);
                    }
                    conn.handles.lock().unwrap().insert(id, Arc::new(Mutex::new(h)));
                    shared.gauges.open_handles.fetch_add(1, Ordering::Relaxed);
                    let lease_gen = lease.as_ref().map(|_| gen);
                    return (
                        Response::ok(
                            gen,
                            Body::Open { handle: id, ident, lease: lease_gen },
                        ),
                        lease,
                    );
                }
                Err(e) => Response::err(0, &e),
            }
        }
        Request::Pread { handle, off, len } => with_handle!(handle, |h| {
            let want = (len as usize).min(protocol::MAX_IO);
            let mut buf = vec![0u8; want];
            match h.file.pread(&mut buf, off) {
                Ok(n) => {
                    buf.truncate(n);
                    Response::ok(gen_of(h), Body::Data(buf))
                }
                Err(e) => Response::err(gen_of(h), &e),
            }
        }),
        Request::Pwrite { handle, off, data } => with_handle!(handle, |h| {
            if data.len() > protocol::MAX_IO {
                return (
                    Response::err_code(
                        ErrCode::InvalidArg,
                        format!("pwrite of {} bytes exceeds MAX_IO", data.len()),
                    ),
                    None,
                );
            }
            match h.file.pwrite(&data, off) {
                Ok(n) => Response::ok(gen_of(h), Body::Written(n as u32)),
                Err(e) => Response::err(gen_of(h), &e),
            }
        }),
        Request::SetLen { handle, len } => with_handle!(handle, |h| {
            match h.file.set_len(len) {
                Ok(()) => Response::ok(gen_of(h), Body::Unit),
                Err(e) => Response::err(gen_of(h), &e),
            }
        }),
        Request::Fsync { handle } => with_handle!(handle, |h| {
            match h.file.fsync() {
                Ok(()) => Response::ok(gen_of(h), Body::Unit),
                Err(e) => Response::err(gen_of(h), &e),
            }
        }),
        Request::Len { handle } => with_handle!(handle, |h| {
            match h.file.len() {
                Ok(n) => Response::ok(gen_of(h), Body::Size(n)),
                Err(e) => Response::err(gen_of(h), &e),
            }
        }),
        Request::Close { handle } => {
            let slot = conn.handles.lock().unwrap().remove(&handle);
            match slot {
                Some(h) => {
                    drop(h); // deferred Sea management runs here
                    shared.gauges.open_handles.fetch_sub(1, Ordering::Relaxed);
                    Response::ok(0, Body::Unit)
                }
                None => {
                    Response::err_code(ErrCode::BadHandle, format!("handle {handle}"))
                }
            }
        }
        Request::MapSync { handle } => with_handle!(handle, |h| {
            match h.file.map_sync() {
                Ok(gen) => Response::ok(gen, Body::Unit),
                Err(e) => Response::err(0, &e),
            }
        }),
        Request::NoteFault { handle, off, len } => with_handle!(handle, |h| {
            h.file.note_map_fault(off, len);
            Response::ok(gen_of(h), Body::Unit)
        }),
        Request::Stat { path } => match shared.fs.size(Path::new(&path)) {
            Ok(n) => Response::ok(0, Body::Size(n)),
            Err(e) => Response::err(0, &e),
        },
        Request::Readdir { path, token } => {
            match shared.fs.readdir(Path::new(&path)) {
                Ok(all) => {
                    // Page the listing: a directory whose encoded
                    // names exceed one frame would otherwise kill the
                    // connection. `token` is the resume index.
                    let start = (token as usize).min(all.len());
                    let mut bytes = 0usize;
                    let mut end = start;
                    while end < all.len() {
                        let cost = 4 + all[end].len();
                        if end > start && bytes + cost > READDIR_PAGE_BYTES {
                            break;
                        }
                        bytes += cost;
                        end += 1;
                    }
                    let next = if end >= all.len() { 0 } else { end as u64 };
                    Response::ok(
                        0,
                        Body::Names { names: all[start..end].to_vec(), next },
                    )
                }
                Err(e) => Response::err(0, &e),
            }
        }
        Request::Rename { from, to } => {
            match shared.fs.rename(Path::new(&from), Path::new(&to)) {
                Ok(()) => Response::ok(0, Body::Unit),
                Err(e) => Response::err(0, &e),
            }
        }
        Request::Unlink { path } => match shared.fs.unlink(Path::new(&path)) {
            Ok(()) => Response::ok(0, Body::Unit),
            Err(e) => Response::err(0, &e),
        },
        Request::Mkdir { path } => match shared.fs.mkdir(Path::new(&path)) {
            Ok(()) => Response::ok(0, Body::Unit),
            Err(e) => Response::err(0, &e),
        },
        Request::SyncMgmt => match shared.fs.sync_mgmt() {
            Ok(()) => Response::ok(0, Body::Unit),
            Err(e) => Response::err(0, &e),
        },
        Request::Counters => {
            let (engine, ledger, counters) = match &shared.sea {
                Some(sea) => {
                    (sea.engine_name().to_string(), sea.ledger(), sea.counters())
                }
                None => (String::from("none"), Vec::new(), Default::default()),
            };
            let g = &shared.gauges;
            Response::ok(
                0,
                Body::Counters(Box::new(CountersReply {
                    engine,
                    ledger,
                    counters,
                    clients_connected: g.clients_connected.load(Ordering::Relaxed),
                    clients_total: g.clients_total.load(Ordering::Relaxed),
                    open_handles: g.open_handles.load(Ordering::Relaxed),
                    ops_served: g.ops_served.load(Ordering::Relaxed),
                    leases_granted: g.leases_granted.load(Ordering::Relaxed),
                    inflight_peak: g.inflight_peak.load(Ordering::Relaxed),
                    // v3 clients get the daemon-side latency
                    // histograms; a v2 connection keeps its frames
                    // byte-compatible by omitting the tail
                    lat: (conn.version >= 3).then(obs::snapshot),
                })),
            )
        }
    };
    (resp, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::RealFs;

    fn scratch(prefix: &str) -> PathBuf {
        crate::vfs::testutil::scratch(prefix)
    }

    fn spawn_real(dir: &Path, socket: &Path) -> Server {
        let fs = Arc::new(RealFs::new(dir).unwrap());
        Server::spawn_vfs(fs, None, ServeCfg::new(socket)).unwrap()
    }

    #[test]
    fn socket_gets_owner_only_permissions() {
        let d = scratch("serve_perms");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        let mode = std::fs::metadata(&sock).unwrap().permissions().mode();
        assert_eq!(mode & 0o777, 0o600, "socket must be 0600, got {mode:o}");
        srv.shutdown().unwrap();
        assert!(!sock.exists(), "shutdown must remove the socket file");
    }

    #[test]
    fn stale_socket_is_reclaimed_live_one_is_not() {
        let d = scratch("serve_stale");
        let sock = d.join("sea.sock");
        // A stale socket file nobody listens on: bind, then drop the
        // listener without removing the file.
        let l = UnixListener::bind(&sock).unwrap();
        drop(l);
        assert!(sock.exists(), "stale socket file should remain after drop");
        let srv = spawn_real(&d, &sock);
        // A second daemon must refuse the *live* socket.
        let err = Server::spawn_vfs(
            Arc::new(RealFs::new(&d).unwrap()),
            None,
            ServeCfg::new(&sock),
        );
        match err {
            Err(Error::Daemon(msg)) => {
                assert!(msg.contains("already serves"), "got: {msg}")
            }
            other => panic!("expected Daemon error, got {other:?}"),
        }
        srv.shutdown().unwrap();
    }

    #[test]
    fn version_mismatch_gets_a_clear_error_frame() {
        let d = scratch("serve_version");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        let mut s = UnixStream::connect(&sock).unwrap();
        let hello = Request::Hello { version: PROTOCOL_VERSION + 7 }.encode();
        write_frame(&mut s, 0, &hello).unwrap();
        let (id, frame) = read_frame(&mut s).unwrap();
        assert_eq!(id, 0, "handshake reply echoes the handshake id");
        let resp = Response::decode(&frame).unwrap();
        let we = resp.body.unwrap_err();
        assert_eq!(we.code, ErrCode::VersionMismatch);
        assert!(
            we.msg
                .contains(&format!("protocol {MIN_PROTOCOL_VERSION}..={PROTOCOL_VERSION}")),
            "got: {}",
            we.msg
        );
        srv.shutdown().unwrap();
    }

    #[test]
    fn v2_client_still_handshakes_and_reads_counters() {
        // Back-compat: a previous-revision client is served at its own
        // revision — the Hello echoes v2, and its Counters frame has no
        // histogram tail (the reply is byte-compatible with v2).
        let d = scratch("serve_v2compat");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        let mut s = UnixStream::connect(&sock).unwrap();
        let hello = Request::Hello { version: MIN_PROTOCOL_VERSION }.encode();
        write_frame(&mut s, 0, &hello).unwrap();
        let (_, frame) = read_frame(&mut s).unwrap();
        match Response::decode(&frame).unwrap().body.unwrap() {
            Body::Hello { version, .. } => assert_eq!(version, MIN_PROTOCOL_VERSION),
            other => panic!("expected Hello body, got {other:?}"),
        }
        write_frame(&mut s, 1, &Request::Counters.encode()).unwrap();
        let (id, frame) = read_frame(&mut s).unwrap();
        assert_eq!(id, 1);
        match Response::decode(&frame).unwrap().body.unwrap() {
            Body::Counters(c) => {
                assert!(c.lat.is_none(), "v2 connection must not get the v3 tail");
            }
            other => panic!("expected Counters body, got {other:?}"),
        }
        srv.shutdown().unwrap();
    }

    #[test]
    fn v3_client_gets_daemon_latency_histograms() {
        // hold the gate so a parallel test can't disable recording
        // while the daemon serves our requests
        let _gate = crate::obs::test_gate();
        let d = scratch("serve_v3lat");
        std::fs::write(d.join("warm.dat"), vec![7u8; 4096]).unwrap();
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        crate::obs::set_enabled(true);
        let fs = crate::vfs::remote::RemoteFs::connect(&sock).unwrap();
        // generate some daemon-side requests so DaemonRequest has data
        let data = fs.read(Path::new("warm.dat")).unwrap();
        assert_eq!(data.len(), 4096);
        let c = fs.counters().unwrap();
        let lat = c.lat.expect("v3 connection carries the histogram tail");
        let daemon = lat
            .get(crate::obs::Metric::DaemonRequest)
            .expect("daemon served requests, so daemon.req has samples");
        assert!(daemon.count > 0);
        assert!(daemon.max > 0, "service time samples are in nanoseconds");
        drop(fs);
        srv.shutdown().unwrap();
    }

    #[test]
    fn non_hello_first_frame_is_rejected() {
        let d = scratch("serve_nohello");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        let mut s = UnixStream::connect(&sock).unwrap();
        write_frame(&mut s, 0, &Request::Counters.encode()).unwrap();
        let (_, frame) = read_frame(&mut s).unwrap();
        let resp = Response::decode(&frame).unwrap();
        assert!(resp.body.is_err());
        srv.shutdown().unwrap();
    }

    #[test]
    fn hello_reply_advertises_a_readahead_hint() {
        let d = scratch("serve_hint");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock);
        let mut s = UnixStream::connect(&sock).unwrap();
        let hello = Request::Hello { version: PROTOCOL_VERSION }.encode();
        write_frame(&mut s, 0, &hello).unwrap();
        let (_, frame) = read_frame(&mut s).unwrap();
        match Response::decode(&frame).unwrap().body.unwrap() {
            Body::Hello { version, chunk_bytes } => {
                assert_eq!(version, PROTOCOL_VERSION);
                assert_eq!(
                    chunk_bytes, DEFAULT_CHUNK_HINT,
                    "non-Sea mounts advertise the default hint"
                );
            }
            other => panic!("expected Hello body, got {other:?}"),
        }
        srv.shutdown().unwrap();
    }
}
