//! Real-bytes pipeline driver: leader/worker incrementation over a
//! [`Vfs`] mount with PJRT compute on the request path.
//!
//! This is the end-to-end proof that the three layers compose: chunk
//! bytes come off a real file system, the per-iteration `chunk + 1` runs
//! on the AOT-compiled HLO through PJRT, integrity is certified by the
//! on-device `block_stats`, and every file placement decision is Sea's.
//!
//! I/O is **streamed**: blocks move through fixed-size stride buffers
//! (one engine chunk per stride) via `pread`/`pwrite` handles, so peak
//! worker memory is one stride regardless of block size — blocks may be
//! any multiple of the lowered chunk geometry.
//!
//! Backpressure: the leader feeds a *bounded* channel; workers pull. A
//! slow tier (rate-limited PFS) therefore throttles the leader instead of
//! queueing unbounded work — the same discipline the paper's Sea daemon
//! applies to flushing.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::Engine;
use crate::vfs::{OpenMode, Vfs, VfsFile};
use crate::workload::dataset::{bytes_to_f32_into, f32_to_bytes_into, Dataset};
use crate::workload::{stream_block, IncrementationSpec, StridePlan};

/// Configuration of a real pipeline run.
pub struct PipelineCfg {
    /// Compiled PJRT engine (chunk geometry must divide the dataset's
    /// block geometry).
    pub engine: Arc<Engine>,
    /// The file system under test (Sea mount or plain/rate-limited dir).
    pub vfs: Arc<dyn Vfs>,
    /// Input dataset (blocks live on the PFS side of `vfs`).
    pub dataset: Dataset,
    /// Mount-prefix for derived files (e.g. `/sea` or `` for direct).
    pub mount_prefix: PathBuf,
    /// Iterations per block.
    pub iterations: usize,
    /// Worker threads.
    pub workers: usize,
    /// Re-read each iteration's file before the next (Algorithm 1's
    /// task-per-iteration structure). When `false`, iterations are
    /// processed in groups of [`PipelineCfg::max_open_outputs`] handles
    /// (each group seeds from the previous group's last file), so the
    /// per-worker fd ceiling is `max_open_outputs + 1` regardless of
    /// `iterations`.
    pub read_back: bool,
    /// Verify on-device stats after every step and fail on corruption.
    pub verify: bool,
    /// Delete intermediate files after their successor is written
    /// (keeps small fast tiers usable on the test box).
    pub cleanup_intermediate: bool,
    /// No-read-back fd budget: max simultaneously-open output handles
    /// per worker (`0` = default 16).
    pub max_open_outputs: usize,
}

/// Default for [`PipelineCfg::max_open_outputs`].
const DEFAULT_MAX_OPEN_OUTPUTS: usize = 16;

/// Measured results of a real pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Wall-clock makespan (seconds) including final `sync_mgmt`.
    pub makespan: f64,
    /// Wall-clock time of the application loop only.
    pub app_time: f64,
    /// Blocks processed.
    pub blocks: usize,
    /// Total bytes read through the VFS.
    pub bytes_read: u64,
    /// Total bytes written through the VFS.
    pub bytes_written: u64,
    /// Per-block processing times (seconds).
    pub block_times: Vec<f64>,
    /// PJRT executions performed.
    pub pjrt_calls: u64,
    /// Mean PJRT time per call (seconds).
    pub pjrt_mean_s: f64,
}

/// Derived-file path for block `b`, iteration `i`.
fn derived_path(prefix: &PathBuf, spec: &IncrementationSpec, b: usize, i: usize) -> PathBuf {
    prefix.join(spec.iter_path(b, i))
}

/// Run the incrementation pipeline for real.
pub fn run_pipeline(cfg: &PipelineCfg) -> Result<PipelineReport> {
    if cfg.iterations == 0 {
        return Err(Error::InvalidArg("iterations must be >= 1".into()));
    }
    let elems = cfg.dataset.elems;
    let stride_elems = cfg.engine.chunk_elems();
    if stride_elems == 0 || elems % stride_elems != 0 {
        return Err(Error::InvalidArg(format!(
            "dataset elems {} not a multiple of engine chunk {}",
            elems, stride_elems
        )));
    }
    let spec = IncrementationSpec {
        blocks: cfg.dataset.blocks.len(),
        file_size: cfg.dataset.block_bytes(),
        iterations: cfg.iterations,
        compute_per_iter: 0.0,
        read_back: cfg.read_back,
    };

    let bytes_read = Arc::new(AtomicU64::new(0));
    let bytes_written = Arc::new(AtomicU64::new(0));
    let block_times = Arc::new(Mutex::new(vec![0f64; spec.blocks]));
    let first_err: Arc<Mutex<Option<Error>>> = Arc::new(Mutex::new(None));
    // snapshot so the report contains only THIS run's PJRT activity
    // (the engine may be shared across runs)
    let timings_before = cfg.engine.timings();

    let t0 = Instant::now();
    // bounded queue: 2 tasks of headroom per worker
    let (tx, rx) = mpsc::sync_channel::<usize>(cfg.workers.max(1) * 2);
    let rx = Arc::new(Mutex::new(rx));

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let engine = cfg.engine.clone();
            let vfs = cfg.vfs.clone();
            let dataset = &cfg.dataset;
            let spec = &spec;
            let prefix = &cfg.mount_prefix;
            let bytes_read = bytes_read.clone();
            let bytes_written = bytes_written.clone();
            let block_times = block_times.clone();
            let first_err = first_err.clone();
            let verify = cfg.verify;
            let read_back = cfg.read_back;
            let cleanup = cfg.cleanup_intermediate;
            let fd_budget = if cfg.max_open_outputs == 0 {
                DEFAULT_MAX_OPEN_OUTPUTS
            } else {
                cfg.max_open_outputs
            };
            handles.push(scope.spawn(move || {
                loop {
                    let b = {
                        let guard = rx.lock().expect("rx poisoned");
                        match guard.recv() {
                            Ok(b) => b,
                            Err(_) => break, // leader done
                        }
                    };
                    let tb = Instant::now();
                    let res = process_block(
                        b, engine.as_ref(), vfs.as_ref(), dataset, spec, prefix,
                        read_back, verify, cleanup, fd_budget,
                        &bytes_read, &bytes_written,
                    );
                    block_times.lock().expect("times poisoned")[b] =
                        tb.elapsed().as_secs_f64();
                    if let Err(e) = res {
                        first_err.lock().expect("err poisoned").get_or_insert(e);
                        break;
                    }
                }
            }));
        }
        // leader: enqueue all blocks (blocks on backpressure)
        for b in 0..spec.blocks {
            if first_err.lock().expect("err poisoned").is_some() {
                break;
            }
            if tx.send(b).is_err() {
                break;
            }
        }
        drop(tx);
        for h in handles {
            let _ = h.join();
        }
    });

    if let Some(e) = first_err.lock().expect("err poisoned").take() {
        return Err(e);
    }
    let app_time = t0.elapsed().as_secs_f64();
    // wait for Sea's flush/evict pool to drain (no-op for plain dirs)
    cfg.vfs.sync_mgmt()?;
    let makespan = t0.elapsed().as_secs_f64();

    let timings = cfg.engine.timings();
    let calls = timings.calls - timings_before.calls;
    let dt = timings.total.saturating_sub(timings_before.total);
    let times = block_times.lock().expect("times poisoned").clone();
    Ok(PipelineReport {
        makespan,
        app_time,
        blocks: spec.blocks,
        bytes_read: bytes_read.load(Ordering::Relaxed),
        bytes_written: bytes_written.load(Ordering::Relaxed),
        block_times: times,
        pjrt_calls: calls,
        pjrt_mean_s: if calls > 0 { dt.as_secs_f64() / calls as f64 } else { 0.0 },
    })
}

/// Process one block, streaming strides through fixed-size buffers: the
/// peak buffer is one engine chunk, never the whole block.
#[allow(clippy::too_many_arguments)]
fn process_block(
    b: usize,
    engine: &Engine,
    vfs: &dyn Vfs,
    dataset: &Dataset,
    spec: &IncrementationSpec,
    prefix: &PathBuf,
    read_back: bool,
    verify: bool,
    cleanup: bool,
    fd_budget: usize,
    bytes_read: &AtomicU64,
    bytes_written: &AtomicU64,
) -> Result<()> {
    let stride_elems = engine.chunk_elems();
    let plan = StridePlan::new(dataset.elems, stride_elems)?;
    let base = dataset.base_of(b);
    // input chunk lives on the "Lustre" (PFS) side of the mount
    let input_rel = PathBuf::from(format!(
        "inputs/{}",
        dataset.blocks[b].file_name().unwrap().to_string_lossy()
    ));

    if read_back {
        // task-per-iteration: each iteration re-reads its predecessor's
        // file, one stride at a time
        for i in 1..=spec.iterations {
            let src = if i == 1 {
                input_rel.clone()
            } else {
                derived_path(prefix, spec, b, i - 1)
            };
            let dst = derived_path(prefix, spec, b, i);
            let moved = stream_block(vfs, &src, &dst, &plan, |_k, chunk| {
                let stats = engine.step(chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                Ok(())
            })?;
            bytes_read.fetch_add(moved, Ordering::Relaxed);
            bytes_written.fetch_add(moved, Ordering::Relaxed);
            if cleanup && i > 1 {
                let prev = derived_path(prefix, spec, b, i - 1);
                let _ = vfs.unlink(&prev);
            }
        }
    } else {
        // single task holding each stride in memory across iteration
        // groups: one pass over the source per group, writing every
        // iteration's file at the stride's offset (no intermediate
        // read-backs, no D_m reads within a group), with at most
        // `fd_budget + 1` handles open at once
        let outs: Vec<PathBuf> = (1..=spec.iterations)
            .map(|i| derived_path(prefix, spec, b, i))
            .collect();
        stream_iteration_groups(
            vfs,
            &input_rel,
            &outs,
            &plan,
            fd_budget,
            |i, chunk| {
                let stats = engine.step(chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                Ok(())
            },
            bytes_read,
            bytes_written,
        )?;
        if cleanup {
            for i in 1..spec.iterations {
                let _ = vfs.unlink(&derived_path(prefix, spec, b, i));
            }
        }
    }
    Ok(())
}

/// Stream `outs.len()` derived iteration files from `input`, holding at
/// most `budget` output handles (plus one source) open at a time.
///
/// Iterations are processed in groups of `budget`: within a group each
/// source stride is read once and every group member's `step` output is
/// written at the stride's offset. The last handle of a group is kept
/// open as the next group's source — it is both still write-pinned (so
/// deferred-mgmt backends like Sea can't evict it mid-read) and the
/// bytes of the iteration the next group resumes from. `step(i, chunk)`
/// advances the chunk from iteration `i-1` to `i` in place (1-based).
#[allow(clippy::too_many_arguments)]
fn stream_iteration_groups(
    vfs: &dyn Vfs,
    input: &Path,
    outs: &[PathBuf],
    plan: &StridePlan,
    budget: usize,
    mut step: impl FnMut(usize, &mut [f32]) -> Result<()>,
    bytes_read: &AtomicU64,
    bytes_written: &AtomicU64,
) -> Result<()> {
    let budget = budget.max(1);
    let mut raw = vec![0u8; plan.stride_bytes()];
    let mut chunk = vec![0f32; plan.stride_elems];
    let mut carry: Option<Box<dyn VfsFile>> = None;
    let mut start = 0usize; // 0-based index into `outs`
    while start < outs.len() {
        let end = (start + budget).min(outs.len());
        let mut group: Vec<Box<dyn VfsFile>> = outs[start..end]
            .iter()
            .map(|p| vfs.open(p, OpenMode::Write))
            .collect::<Result<_>>()?;
        let mut src: Box<dyn VfsFile> = match carry.take() {
            Some(h) => h, // previous group's last output, still open
            None => vfs.open(input, OpenMode::Read)?,
        };
        for k in 0..plan.strides() {
            let off = plan.offset(k);
            src.pread_exact(&mut raw, off)?;
            bytes_read.fetch_add(raw.len() as u64, Ordering::Relaxed);
            bytes_to_f32_into(&raw, &mut chunk)?;
            for (idx, out) in group.iter_mut().enumerate() {
                step(start + idx + 1, &mut chunk)?;
                f32_to_bytes_into(&chunk, &mut raw);
                out.pwrite_all(&raw, off)?;
                bytes_written.fetch_add(raw.len() as u64, Ordering::Relaxed);
            }
        }
        drop(src);
        if end < outs.len() {
            // keep the boundary file's handle: next group reads from it
            carry = group.pop();
        }
        drop(group); // close writers: Sea's deferred mgmt fires here
        start = end;
    }
    drop(carry);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::RealFs;
    use crate::workload::dataset::f32_to_bytes_into as to_bytes;
    use std::sync::atomic::AtomicUsize;

    /// Vfs decorator counting concurrently-open handles (the fd ceiling).
    struct CountingVfs {
        inner: RealFs,
        open_now: Arc<AtomicUsize>,
        peak: Arc<AtomicUsize>,
    }

    struct CountingFile {
        inner: Box<dyn VfsFile>,
        open_now: Arc<AtomicUsize>,
    }

    impl Drop for CountingFile {
        fn drop(&mut self) {
            self.open_now.fetch_sub(1, Ordering::Relaxed);
        }
    }

    impl VfsFile for CountingFile {
        fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
            self.inner.pread(buf, off)
        }
        fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
            self.inner.pwrite(data, off)
        }
        fn set_len(&mut self, len: u64) -> Result<()> {
            self.inner.set_len(len)
        }
        fn fsync(&mut self) -> Result<()> {
            self.inner.fsync()
        }
        fn len(&self) -> Result<u64> {
            self.inner.len()
        }
    }

    impl Vfs for CountingVfs {
        fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
            let inner = self.inner.open(path, mode)?;
            let now = self.open_now.fetch_add(1, Ordering::Relaxed) + 1;
            self.peak.fetch_max(now, Ordering::Relaxed);
            Ok(Box::new(CountingFile { inner, open_now: self.open_now.clone() }))
        }
        fn unlink(&self, path: &Path) -> Result<()> {
            self.inner.unlink(path)
        }
        fn exists(&self, path: &Path) -> bool {
            self.inner.exists(path)
        }
        fn size(&self, path: &Path) -> Result<u64> {
            self.inner.size(path)
        }
        fn rename(&self, from: &Path, to: &Path) -> Result<()> {
            self.inner.rename(from, to)
        }
        fn readdir(&self, path: &Path) -> Result<Vec<String>> {
            self.inner.readdir(path)
        }
    }

    use std::path::Path;

    #[test]
    fn no_read_back_streaming_respects_fd_budget() {
        // regression for the known limit: the no-read-back path used to
        // hold one fd open per iteration; with a budget of 4 the ceiling
        // must stay at budget + 1 (outputs + the group source) even for
        // 40 iterations
        let dir = std::env::temp_dir()
            .join(format!("sea_fdbudget_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let vfs = CountingVfs {
            inner: RealFs::new(&dir).unwrap(),
            open_now: Arc::new(AtomicUsize::new(0)),
            peak: Arc::new(AtomicUsize::new(0)),
        };
        // 64-element input block, 16-element strides, base value 5.0
        let elems = 64usize;
        let base = 5.0f32;
        let input = PathBuf::from("inputs/block.dat");
        let mut raw = vec![0u8; elems * 4];
        to_bytes(&vec![base; elems], &mut raw);
        vfs.write(&input, &raw).unwrap();

        let iterations = 40usize;
        let budget = 4usize;
        let outs: Vec<PathBuf> =
            (1..=iterations).map(|i| PathBuf::from(format!("out/iter{i:02}.dat"))).collect();
        let plan = StridePlan::new(elems, 16).unwrap();
        let br = AtomicU64::new(0);
        let bw = AtomicU64::new(0);
        stream_iteration_groups(
            &vfs,
            &input,
            &outs,
            &plan,
            budget,
            |_i, chunk| {
                for x in chunk.iter_mut() {
                    *x += 1.0;
                }
                Ok(())
            },
            &br,
            &bw,
        )
        .unwrap();

        let peak = vfs.peak.load(Ordering::Relaxed);
        assert!(peak <= budget + 1, "fd ceiling exceeded: peak {peak}");
        assert_eq!(vfs.open_now.load(Ordering::Relaxed), 0, "all handles closed");
        // every iteration file holds base + i across all strides
        for (idx, p) in outs.iter().enumerate() {
            let got = vfs.read(p).unwrap();
            assert_eq!(got.len(), elems * 4);
            for (e, quad) in got.chunks(4).enumerate() {
                let v = f32::from_le_bytes([quad[0], quad[1], quad[2], quad[3]]);
                assert_eq!(v, base + (idx + 1) as f32, "iter {} elem {e}", idx + 1);
            }
        }
        // group-boundary re-reads: 40 iterations / budget 4 = 10 sources
        assert_eq!(br.load(Ordering::Relaxed), (elems * 4 * 10) as u64);
        assert_eq!(bw.load(Ordering::Relaxed), (elems * 4 * iterations) as u64);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
