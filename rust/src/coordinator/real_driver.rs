//! Real-bytes pipeline driver: leader/worker incrementation over a
//! [`Vfs`] mount with PJRT compute on the request path.
//!
//! This is the end-to-end proof that the three layers compose: chunk
//! bytes come off a real file system, the per-iteration `chunk + 1` runs
//! on the AOT-compiled HLO through PJRT, integrity is certified by the
//! on-device `block_stats`, and every file placement decision is Sea's.
//!
//! I/O is **streamed**: blocks move through fixed-size stride buffers
//! (one engine chunk per stride) via `pread`/`pwrite` handles, so peak
//! worker memory is one stride regardless of block size — blocks may be
//! any multiple of the lowered chunk geometry.
//!
//! Backpressure: the leader feeds a *bounded* channel; workers pull. A
//! slow tier (rate-limited PFS) therefore throttles the leader instead of
//! queueing unbounded work — the same discipline the paper's Sea daemon
//! applies to flushing.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::Engine;
use crate::vfs::{OpenMode, Vfs, VfsFile};
use crate::workload::dataset::{bytes_to_f32_into, f32_to_bytes_into, Dataset};
use crate::workload::{stream_block, IncrementationSpec, StridePlan};

/// Configuration of a real pipeline run.
pub struct PipelineCfg {
    /// Compiled PJRT engine (chunk geometry must divide the dataset's
    /// block geometry).
    pub engine: Arc<Engine>,
    /// The file system under test (Sea mount or plain/rate-limited dir).
    pub vfs: Arc<dyn Vfs>,
    /// Input dataset (blocks live on the PFS side of `vfs`).
    pub dataset: Dataset,
    /// Mount-prefix for derived files (e.g. `/sea` or `` for direct).
    pub mount_prefix: PathBuf,
    /// Iterations per block.
    pub iterations: usize,
    /// Worker threads.
    pub workers: usize,
    /// Re-read each iteration's file before the next (Algorithm 1's
    /// task-per-iteration structure). When `false`, each worker holds
    /// one open output handle *per iteration* simultaneously (no
    /// intermediate reads), so `workers × iterations` must stay well
    /// under the process fd limit.
    pub read_back: bool,
    /// Verify on-device stats after every step and fail on corruption.
    pub verify: bool,
    /// Delete intermediate files after their successor is written
    /// (keeps small fast tiers usable on the test box).
    pub cleanup_intermediate: bool,
}

/// Measured results of a real pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Wall-clock makespan (seconds) including final `sync_mgmt`.
    pub makespan: f64,
    /// Wall-clock time of the application loop only.
    pub app_time: f64,
    /// Blocks processed.
    pub blocks: usize,
    /// Total bytes read through the VFS.
    pub bytes_read: u64,
    /// Total bytes written through the VFS.
    pub bytes_written: u64,
    /// Per-block processing times (seconds).
    pub block_times: Vec<f64>,
    /// PJRT executions performed.
    pub pjrt_calls: u64,
    /// Mean PJRT time per call (seconds).
    pub pjrt_mean_s: f64,
}

/// Derived-file path for block `b`, iteration `i`.
fn derived_path(prefix: &PathBuf, spec: &IncrementationSpec, b: usize, i: usize) -> PathBuf {
    prefix.join(spec.iter_path(b, i))
}

/// Run the incrementation pipeline for real.
pub fn run_pipeline(cfg: &PipelineCfg) -> Result<PipelineReport> {
    if cfg.iterations == 0 {
        return Err(Error::InvalidArg("iterations must be >= 1".into()));
    }
    let elems = cfg.dataset.elems;
    let stride_elems = cfg.engine.chunk_elems();
    if stride_elems == 0 || elems % stride_elems != 0 {
        return Err(Error::InvalidArg(format!(
            "dataset elems {} not a multiple of engine chunk {}",
            elems, stride_elems
        )));
    }
    let spec = IncrementationSpec {
        blocks: cfg.dataset.blocks.len(),
        file_size: cfg.dataset.block_bytes(),
        iterations: cfg.iterations,
        compute_per_iter: 0.0,
        read_back: cfg.read_back,
    };

    let bytes_read = Arc::new(AtomicU64::new(0));
    let bytes_written = Arc::new(AtomicU64::new(0));
    let block_times = Arc::new(Mutex::new(vec![0f64; spec.blocks]));
    let first_err: Arc<Mutex<Option<Error>>> = Arc::new(Mutex::new(None));
    // snapshot so the report contains only THIS run's PJRT activity
    // (the engine may be shared across runs)
    let timings_before = cfg.engine.timings();

    let t0 = Instant::now();
    // bounded queue: 2 tasks of headroom per worker
    let (tx, rx) = mpsc::sync_channel::<usize>(cfg.workers.max(1) * 2);
    let rx = Arc::new(Mutex::new(rx));

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let engine = cfg.engine.clone();
            let vfs = cfg.vfs.clone();
            let dataset = &cfg.dataset;
            let spec = &spec;
            let prefix = &cfg.mount_prefix;
            let bytes_read = bytes_read.clone();
            let bytes_written = bytes_written.clone();
            let block_times = block_times.clone();
            let first_err = first_err.clone();
            let verify = cfg.verify;
            let read_back = cfg.read_back;
            let cleanup = cfg.cleanup_intermediate;
            handles.push(scope.spawn(move || {
                loop {
                    let b = {
                        let guard = rx.lock().expect("rx poisoned");
                        match guard.recv() {
                            Ok(b) => b,
                            Err(_) => break, // leader done
                        }
                    };
                    let tb = Instant::now();
                    let res = process_block(
                        b, engine.as_ref(), vfs.as_ref(), dataset, spec, prefix,
                        read_back, verify, cleanup,
                        &bytes_read, &bytes_written,
                    );
                    block_times.lock().expect("times poisoned")[b] =
                        tb.elapsed().as_secs_f64();
                    if let Err(e) = res {
                        first_err.lock().expect("err poisoned").get_or_insert(e);
                        break;
                    }
                }
            }));
        }
        // leader: enqueue all blocks (blocks on backpressure)
        for b in 0..spec.blocks {
            if first_err.lock().expect("err poisoned").is_some() {
                break;
            }
            if tx.send(b).is_err() {
                break;
            }
        }
        drop(tx);
        for h in handles {
            let _ = h.join();
        }
    });

    if let Some(e) = first_err.lock().expect("err poisoned").take() {
        return Err(e);
    }
    let app_time = t0.elapsed().as_secs_f64();
    // wait for Sea's flush/evict pool to drain (no-op for plain dirs)
    cfg.vfs.sync_mgmt()?;
    let makespan = t0.elapsed().as_secs_f64();

    let timings = cfg.engine.timings();
    let calls = timings.calls - timings_before.calls;
    let dt = timings.total.saturating_sub(timings_before.total);
    let times = block_times.lock().expect("times poisoned").clone();
    Ok(PipelineReport {
        makespan,
        app_time,
        blocks: spec.blocks,
        bytes_read: bytes_read.load(Ordering::Relaxed),
        bytes_written: bytes_written.load(Ordering::Relaxed),
        block_times: times,
        pjrt_calls: calls,
        pjrt_mean_s: if calls > 0 { dt.as_secs_f64() / calls as f64 } else { 0.0 },
    })
}

/// Process one block, streaming strides through fixed-size buffers: the
/// peak buffer is one engine chunk, never the whole block.
#[allow(clippy::too_many_arguments)]
fn process_block(
    b: usize,
    engine: &Engine,
    vfs: &dyn Vfs,
    dataset: &Dataset,
    spec: &IncrementationSpec,
    prefix: &PathBuf,
    read_back: bool,
    verify: bool,
    cleanup: bool,
    bytes_read: &AtomicU64,
    bytes_written: &AtomicU64,
) -> Result<()> {
    let stride_elems = engine.chunk_elems();
    let plan = StridePlan::new(dataset.elems, stride_elems)?;
    let base = dataset.base_of(b);
    // input chunk lives on the "Lustre" (PFS) side of the mount
    let input_rel = PathBuf::from(format!(
        "inputs/{}",
        dataset.blocks[b].file_name().unwrap().to_string_lossy()
    ));

    if read_back {
        // task-per-iteration: each iteration re-reads its predecessor's
        // file, one stride at a time
        for i in 1..=spec.iterations {
            let src = if i == 1 {
                input_rel.clone()
            } else {
                derived_path(prefix, spec, b, i - 1)
            };
            let dst = derived_path(prefix, spec, b, i);
            let moved = stream_block(vfs, &src, &dst, &plan, |_k, chunk| {
                let stats = engine.step(chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                Ok(())
            })?;
            bytes_read.fetch_add(moved, Ordering::Relaxed);
            bytes_written.fetch_add(moved, Ordering::Relaxed);
            if cleanup && i > 1 {
                let prev = derived_path(prefix, spec, b, i - 1);
                let _ = vfs.unlink(&prev);
            }
        }
    } else {
        // single task holding each stride in memory across iterations:
        // one pass over the input, writing every iteration's file at the
        // stride's offset (no intermediate read-backs, no D_m reads)
        let mut outs: Vec<Box<dyn VfsFile>> = (1..=spec.iterations)
            .map(|i| vfs.open(&derived_path(prefix, spec, b, i), OpenMode::Write))
            .collect::<Result<_>>()?;
        let mut src = vfs.open(&input_rel, OpenMode::Read)?;
        let mut raw = vec![0u8; plan.stride_bytes()];
        let mut chunk = vec![0f32; stride_elems];
        for k in 0..plan.strides() {
            let off = plan.offset(k);
            src.pread_exact(&mut raw, off)?;
            bytes_read.fetch_add(raw.len() as u64, Ordering::Relaxed);
            bytes_to_f32_into(&raw, &mut chunk)?;
            for (idx, out) in outs.iter_mut().enumerate() {
                let i = idx + 1;
                let stats = engine.step(&mut chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                f32_to_bytes_into(&chunk, &mut raw);
                out.pwrite_all(&raw, off)?;
                bytes_written.fetch_add(raw.len() as u64, Ordering::Relaxed);
            }
        }
        drop(outs); // close writers: Sea's deferred mgmt fires here
        if cleanup {
            for i in 1..spec.iterations {
                let _ = vfs.unlink(&derived_path(prefix, spec, b, i));
            }
        }
    }
    Ok(())
}
