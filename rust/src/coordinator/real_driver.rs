//! Real-bytes pipeline driver: leader/worker incrementation over a
//! [`Vfs`] mount with PJRT compute on the request path.
//!
//! This is the end-to-end proof that the three layers compose: chunk
//! bytes come off a real file system, the per-iteration `chunk + 1` runs
//! on the AOT-compiled HLO through PJRT, integrity is certified by the
//! on-device `block_stats`, and every file placement decision is Sea's.
//!
//! I/O is **streamed**: blocks move through fixed-size stride buffers
//! (one engine chunk per stride) via `pread`/`pwrite` handles, so peak
//! worker memory is one stride regardless of block size — blocks may be
//! any multiple of the lowered chunk geometry.
//!
//! Backpressure: the leader feeds a *bounded* channel; workers pull. A
//! slow tier (rate-limited PFS) therefore throttles the leader instead of
//! queueing unbounded work — the same discipline the paper's Sea daemon
//! applies to flushing.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::Engine;
use crate::vfs::pages::{self, MapMode, PageCache};
use crate::vfs::{OpenMode, Vfs, VfsFile};
use crate::workload::dataset::{bytes_to_f32_into, f32_to_bytes_into, Dataset};
use crate::workload::{stream_block, IncrementationSpec, StridePlan};

/// How workers move block bytes (`sea run --io-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoMode {
    /// One `pread` + one `pwrite` per stride through bounded buffers.
    #[default]
    Streamed,
    /// mmap-style: strides read/write [`crate::vfs::MappedView`]s over
    /// the block files — page faults via the VFS [`PageCache`], dirty
    /// pages written back on `msync`. Emulates nibabel/numpy-style
    /// consumers that map their block files.
    Mmap,
}

impl IoMode {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<IoMode> {
        match s {
            "streamed" | "stream" => Some(IoMode::Streamed),
            "mmap" | "mapped" => Some(IoMode::Mmap),
            _ => None,
        }
    }

    /// Canonical token.
    pub fn name(self) -> &'static str {
        match self {
            IoMode::Streamed => "streamed",
            IoMode::Mmap => "mmap",
        }
    }
}

/// Configuration of a real pipeline run.
pub struct PipelineCfg {
    /// Compiled PJRT engine (chunk geometry must divide the dataset's
    /// block geometry).
    pub engine: Arc<Engine>,
    /// The file system under test (Sea mount or plain/rate-limited dir).
    pub vfs: Arc<dyn Vfs>,
    /// Input dataset (blocks live on the PFS side of `vfs`).
    pub dataset: Dataset,
    /// Mount-prefix for derived files (e.g. `/sea` or `` for direct).
    pub mount_prefix: PathBuf,
    /// Iterations per block.
    pub iterations: usize,
    /// Worker threads.
    pub workers: usize,
    /// Re-read each iteration's file before the next (Algorithm 1's
    /// task-per-iteration structure). When `false`, iterations are
    /// processed in groups of [`PipelineCfg::max_open_outputs`] handles
    /// (each group seeds from the previous group's last file), so the
    /// per-worker fd ceiling is `max_open_outputs + 1` regardless of
    /// `iterations`.
    pub read_back: bool,
    /// Verify on-device stats after every step and fail on corruption.
    pub verify: bool,
    /// Delete intermediate files after their successor is written
    /// (keeps small fast tiers usable on the test box).
    pub cleanup_intermediate: bool,
    /// No-read-back fd budget: max simultaneously-open output handles
    /// per worker (`0` = default 16).
    pub max_open_outputs: usize,
    /// Stride I/O flavour: `pread`/`pwrite` streaming, or mapped views
    /// over the [`PageCache`] (requires [`PipelineCfg::read_back`]).
    pub io_mode: IoMode,
    /// Explicit cache for mapped mode. `None` falls back to the
    /// mount's own cache ([`Vfs::page_cache`] — a Sea mount's gauges
    /// then land on `sea stat`) and finally the process-wide default.
    /// Callers comparing backends (e.g. `sea run --mode both`) should
    /// pass an equally-tuned cache for mounts that carry none, or the
    /// page knobs silently differ between the runs.
    pub page_cache: Option<Arc<PageCache>>,
}

/// Default for [`PipelineCfg::max_open_outputs`].
const DEFAULT_MAX_OPEN_OUTPUTS: usize = 16;

/// Measured results of a real pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Wall-clock makespan (seconds) including final `sync_mgmt`.
    pub makespan: f64,
    /// Wall-clock time of the application loop only.
    pub app_time: f64,
    /// Blocks processed.
    pub blocks: usize,
    /// Total bytes read through the VFS.
    pub bytes_read: u64,
    /// Total bytes written through the VFS.
    pub bytes_written: u64,
    /// Per-block processing times (seconds).
    pub block_times: Vec<f64>,
    /// PJRT executions performed.
    pub pjrt_calls: u64,
    /// Mean PJRT time per call (seconds).
    pub pjrt_mean_s: f64,
}

/// Derived-file path for block `b`, iteration `i`.
fn derived_path(prefix: &PathBuf, spec: &IncrementationSpec, b: usize, i: usize) -> PathBuf {
    prefix.join(spec.iter_path(b, i))
}

/// Run the incrementation pipeline for real.
pub fn run_pipeline(cfg: &PipelineCfg) -> Result<PipelineReport> {
    if cfg.iterations == 0 {
        return Err(Error::InvalidArg("iterations must be >= 1".into()));
    }
    if cfg.io_mode == IoMode::Mmap && !cfg.read_back {
        return Err(Error::InvalidArg(
            "--io-mode mmap models a mapped consumer re-reading each iteration; \
             combine it with read-back (drop --no-read-back)"
                .into(),
        ));
    }
    // mapped mode faults through a PageCache: the caller's explicit
    // one, else the mount's own (so its gauges land on `sea stat`),
    // else the process-wide default
    let page_cache: Option<Arc<PageCache>> = match cfg.io_mode {
        IoMode::Mmap => Some(
            cfg.page_cache
                .clone()
                .or_else(|| cfg.vfs.page_cache())
                .unwrap_or_else(|| pages::global().clone()),
        ),
        IoMode::Streamed => None,
    };
    // dirty pages pin the budget until written back and W workers each
    // hold a write view, so cap each view's dirty set at a 1/(4W) slice
    // — the shared budget stays the binding memory bound
    let wb_batch = page_cache.as_ref().map_or(0, |c| {
        (c.budget() / (4 * cfg.workers.max(1) as u64)).max(c.page_bytes() as u64)
    });
    let elems = cfg.dataset.elems;
    let stride_elems = cfg.engine.chunk_elems();
    if stride_elems == 0 || elems % stride_elems != 0 {
        return Err(Error::InvalidArg(format!(
            "dataset elems {} not a multiple of engine chunk {}",
            elems, stride_elems
        )));
    }
    let spec = IncrementationSpec {
        blocks: cfg.dataset.blocks.len(),
        file_size: cfg.dataset.block_bytes(),
        iterations: cfg.iterations,
        compute_per_iter: 0.0,
        read_back: cfg.read_back,
    };

    let bytes_read = Arc::new(AtomicU64::new(0));
    let bytes_written = Arc::new(AtomicU64::new(0));
    let block_times = Arc::new(Mutex::new(vec![0f64; spec.blocks]));
    let first_err: Arc<Mutex<Option<Error>>> = Arc::new(Mutex::new(None));
    // snapshot so the report contains only THIS run's PJRT activity
    // (the engine may be shared across runs)
    let timings_before = cfg.engine.timings();

    let t0 = Instant::now();
    // bounded queue: 2 tasks of headroom per worker
    let (tx, rx) = mpsc::sync_channel::<usize>(cfg.workers.max(1) * 2);
    let rx = Arc::new(Mutex::new(rx));

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let engine = cfg.engine.clone();
            let vfs = cfg.vfs.clone();
            let dataset = &cfg.dataset;
            let spec = &spec;
            let prefix = &cfg.mount_prefix;
            let bytes_read = bytes_read.clone();
            let bytes_written = bytes_written.clone();
            let block_times = block_times.clone();
            let first_err = first_err.clone();
            let verify = cfg.verify;
            let read_back = cfg.read_back;
            let cleanup = cfg.cleanup_intermediate;
            let page_cache = page_cache.clone();
            let fd_budget = if cfg.max_open_outputs == 0 {
                DEFAULT_MAX_OPEN_OUTPUTS
            } else {
                cfg.max_open_outputs
            };
            handles.push(scope.spawn(move || {
                loop {
                    let b = {
                        let guard = rx.lock().expect("rx poisoned");
                        match guard.recv() {
                            Ok(b) => b,
                            Err(_) => break, // leader done
                        }
                    };
                    let tb = Instant::now();
                    let res = process_block(
                        b, engine.as_ref(), vfs.as_ref(), dataset, spec, prefix,
                        read_back, verify, cleanup, fd_budget, page_cache.as_ref(),
                        wb_batch, &bytes_read, &bytes_written,
                    );
                    block_times.lock().expect("times poisoned")[b] =
                        tb.elapsed().as_secs_f64();
                    if let Err(e) = res {
                        first_err.lock().expect("err poisoned").get_or_insert(e);
                        break;
                    }
                }
            }));
        }
        // leader: enqueue all blocks (blocks on backpressure)
        for b in 0..spec.blocks {
            if first_err.lock().expect("err poisoned").is_some() {
                break;
            }
            if tx.send(b).is_err() {
                break;
            }
        }
        drop(tx);
        for h in handles {
            let _ = h.join();
        }
    });

    if let Some(e) = first_err.lock().expect("err poisoned").take() {
        return Err(e);
    }
    let app_time = t0.elapsed().as_secs_f64();
    // wait for Sea's flush/evict pool to drain (no-op for plain dirs)
    cfg.vfs.sync_mgmt()?;
    let makespan = t0.elapsed().as_secs_f64();

    let timings = cfg.engine.timings();
    let calls = timings.calls - timings_before.calls;
    let dt = timings.total.saturating_sub(timings_before.total);
    let times = block_times.lock().expect("times poisoned").clone();
    Ok(PipelineReport {
        makespan,
        app_time,
        blocks: spec.blocks,
        bytes_read: bytes_read.load(Ordering::Relaxed),
        bytes_written: bytes_written.load(Ordering::Relaxed),
        block_times: times,
        pjrt_calls: calls,
        pjrt_mean_s: if calls > 0 { dt.as_secs_f64() / calls as f64 } else { 0.0 },
    })
}

/// Process one block, streaming strides through fixed-size buffers: the
/// peak buffer is one engine chunk, never the whole block (and, in
/// mapped mode, never more than the page-cache budget).
#[allow(clippy::too_many_arguments)]
fn process_block(
    b: usize,
    engine: &Engine,
    vfs: &dyn Vfs,
    dataset: &Dataset,
    spec: &IncrementationSpec,
    prefix: &PathBuf,
    read_back: bool,
    verify: bool,
    cleanup: bool,
    fd_budget: usize,
    page_cache: Option<&Arc<PageCache>>,
    wb_batch: u64,
    bytes_read: &AtomicU64,
    bytes_written: &AtomicU64,
) -> Result<()> {
    let stride_elems = engine.chunk_elems();
    let plan = StridePlan::new(dataset.elems, stride_elems)?;
    let base = dataset.base_of(b);
    // input chunk lives on the "Lustre" (PFS) side of the mount
    let input_rel = PathBuf::from(format!(
        "inputs/{}",
        dataset.blocks[b].file_name().unwrap().to_string_lossy()
    ));

    if read_back {
        // task-per-iteration: each iteration re-reads its predecessor's
        // file, one stride at a time (or one page fault at a time in
        // mapped mode)
        for i in 1..=spec.iterations {
            let src = if i == 1 {
                input_rel.clone()
            } else {
                derived_path(prefix, spec, b, i - 1)
            };
            let dst = derived_path(prefix, spec, b, i);
            let step = |_k: usize, chunk: &mut [f32]| {
                let stats = engine.step(chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                Ok(())
            };
            let moved = match page_cache {
                Some(cache) => mmap_block_step(vfs, cache, &src, &dst, &plan, wb_batch, step)?,
                None => stream_block(vfs, &src, &dst, &plan, step)?,
            };
            bytes_read.fetch_add(moved, Ordering::Relaxed);
            bytes_written.fetch_add(moved, Ordering::Relaxed);
            if cleanup && i > 1 {
                let prev = derived_path(prefix, spec, b, i - 1);
                let _ = vfs.unlink(&prev);
            }
        }
    } else {
        // single task holding each stride in memory across iteration
        // groups: one pass over the source per group, writing every
        // iteration's file at the stride's offset (no intermediate
        // read-backs, no D_m reads within a group), with at most
        // `fd_budget + 1` handles open at once
        let outs: Vec<PathBuf> = (1..=spec.iterations)
            .map(|i| derived_path(prefix, spec, b, i))
            .collect();
        stream_iteration_groups(
            vfs,
            &input_rel,
            &outs,
            &plan,
            fd_budget,
            |i, chunk| {
                let stats = engine.step(chunk)?;
                if verify {
                    stats
                        .certify_uniform(base + i as f32, stride_elems)
                        .map_err(|e| Error::Integrity(format!("block {b} iter {i}: {e}")))?;
                }
                Ok(())
            },
            bytes_read,
            bytes_written,
        )?;
        if cleanup {
            for i in 1..spec.iterations {
                let _ = vfs.unlink(&derived_path(prefix, spec, b, i));
            }
        }
    }
    Ok(())
}

/// Stream `outs.len()` derived iteration files from `input`, holding at
/// most `budget` output handles (plus one source) open at a time.
///
/// Iterations are processed in groups of `budget`: within a group each
/// source stride is read once and every group member's `step` output is
/// written at the stride's offset. The last handle of a group is kept
/// open as the next group's source — it is both still write-pinned (so
/// deferred-mgmt backends like Sea can't evict it mid-read) and the
/// bytes of the iteration the next group resumes from. `step(i, chunk)`
/// advances the chunk from iteration `i-1` to `i` in place (1-based).
#[allow(clippy::too_many_arguments)]
fn stream_iteration_groups(
    vfs: &dyn Vfs,
    input: &Path,
    outs: &[PathBuf],
    plan: &StridePlan,
    budget: usize,
    mut step: impl FnMut(usize, &mut [f32]) -> Result<()>,
    bytes_read: &AtomicU64,
    bytes_written: &AtomicU64,
) -> Result<()> {
    let budget = budget.max(1);
    let mut raw = vec![0u8; plan.stride_bytes()];
    let mut chunk = vec![0f32; plan.stride_elems];
    let mut carry: Option<Box<dyn VfsFile>> = None;
    let mut start = 0usize; // 0-based index into `outs`
    while start < outs.len() {
        let end = (start + budget).min(outs.len());
        let mut group: Vec<Box<dyn VfsFile>> = outs[start..end]
            .iter()
            .map(|p| vfs.open(p, OpenMode::Write))
            .collect::<Result<_>>()?;
        let mut src: Box<dyn VfsFile> = match carry.take() {
            Some(h) => h, // previous group's last output, still open
            None => vfs.open(input, OpenMode::Read)?,
        };
        for k in 0..plan.strides() {
            let off = plan.offset(k);
            src.pread_exact(&mut raw, off)?;
            bytes_read.fetch_add(raw.len() as u64, Ordering::Relaxed);
            bytes_to_f32_into(&raw, &mut chunk)?;
            for (idx, out) in group.iter_mut().enumerate() {
                step(start + idx + 1, &mut chunk)?;
                f32_to_bytes_into(&chunk, &mut raw);
                out.pwrite_all(&raw, off)?;
                bytes_written.fetch_add(raw.len() as u64, Ordering::Relaxed);
            }
        }
        drop(src);
        if end < outs.len() {
            // keep the boundary file's handle: next group reads from it
            carry = group.pop();
        }
        drop(group); // close writers: Sea's deferred mgmt fires here
        start = end;
    }
    drop(carry);
    Ok(())
}

/// One mapped iteration: stride bytes come off a read view of `src`
/// and land in a write view of `dst` (sized up front — a mapping
/// cannot grow a file), with dirty pages written back by `msync` at
/// the end. Faults are page-granular through the shared cache, so
/// peak I/O memory is bounded by the cache budget however large the
/// block is. `step(k, chunk)` mutates stride `k` in place.
fn mmap_block_step(
    vfs: &dyn Vfs,
    cache: &Arc<PageCache>,
    src: &Path,
    dst: &Path,
    plan: &StridePlan,
    wb_batch: u64,
    mut step: impl FnMut(usize, &mut [f32]) -> Result<()>,
) -> Result<u64> {
    let mut src_f = vfs.open(src, OpenMode::Read)?;
    let mut dst_f = vfs.open(dst, OpenMode::Write)?;
    dst_f.set_len(plan.block_bytes())?;
    let mut raw = vec![0u8; plan.stride_bytes()];
    let mut elems = vec![0f32; plan.stride_elems];
    let mut src_view = src_f.map(cache, 0, plan.block_bytes(), MapMode::Read)?;
    let mut dst_view = dst_f.map(cache, 0, plan.block_bytes(), MapMode::Write)?;
    for k in 0..plan.strides() {
        let off = plan.offset(k);
        let n = src_view.read_at(&mut raw, off)?;
        if n != raw.len() {
            return Err(Error::io(
                src,
                std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("mapped stride {k}: {n}/{} bytes", raw.len()),
                ),
            ));
        }
        bytes_to_f32_into(&raw, &mut elems)?;
        step(k, &mut elems)?;
        f32_to_bytes_into(&elems, &mut raw);
        // sequential scan: release the consumed source pages eagerly
        src_view.advise_dontneed(off, raw.len() as u64);
        // dirty pages pin the shared budget (another view's faults
        // cannot reclaim them), so write the stride in wb_batch-sized
        // slices and msync between slices: no view ever pins much more
        // than its 1/(4·workers) slice of the cache
        let batch = wb_batch.max(1) as usize;
        let mut done = 0usize;
        while done < raw.len() {
            let take = (raw.len() - done).min(batch);
            dst_view.write_at(&raw[done..done + take], off + done as u64)?;
            if dst_view.dirty_bytes() >= batch as u64 {
                dst_view.msync()?;
            }
            done += take;
        }
    }
    dst_view.msync()?;
    Ok(plan.block_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::RealFs;
    use crate::workload::dataset::f32_to_bytes_into as to_bytes;
    use std::sync::atomic::AtomicUsize;

    /// Vfs decorator counting concurrently-open handles (the fd ceiling).
    struct CountingVfs {
        inner: RealFs,
        open_now: Arc<AtomicUsize>,
        peak: Arc<AtomicUsize>,
    }

    struct CountingFile {
        inner: Box<dyn VfsFile>,
        open_now: Arc<AtomicUsize>,
    }

    impl Drop for CountingFile {
        fn drop(&mut self) {
            self.open_now.fetch_sub(1, Ordering::Relaxed);
        }
    }

    impl VfsFile for CountingFile {
        fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
            self.inner.pread(buf, off)
        }
        fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
            self.inner.pwrite(data, off)
        }
        fn set_len(&mut self, len: u64) -> Result<()> {
            self.inner.set_len(len)
        }
        fn fsync(&mut self) -> Result<()> {
            self.inner.fsync()
        }
        fn len(&self) -> Result<u64> {
            self.inner.len()
        }
    }

    impl Vfs for CountingVfs {
        fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
            let inner = self.inner.open(path, mode)?;
            let now = self.open_now.fetch_add(1, Ordering::Relaxed) + 1;
            self.peak.fetch_max(now, Ordering::Relaxed);
            Ok(Box::new(CountingFile { inner, open_now: self.open_now.clone() }))
        }
        fn unlink(&self, path: &Path) -> Result<()> {
            self.inner.unlink(path)
        }
        fn exists(&self, path: &Path) -> bool {
            self.inner.exists(path)
        }
        fn size(&self, path: &Path) -> Result<u64> {
            self.inner.size(path)
        }
        fn rename(&self, from: &Path, to: &Path) -> Result<()> {
            self.inner.rename(from, to)
        }
        fn readdir(&self, path: &Path) -> Result<Vec<String>> {
            self.inner.readdir(path)
        }
    }

    use std::path::Path;

    #[test]
    fn mmap_block_step_matches_stream_block() {
        // ISSUE 5: the mapped iteration path produces byte-identical
        // outputs to the streamed one, under a budget far below the
        // block size
        let dir = std::env::temp_dir()
            .join(format!("sea_mmapstep_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let vfs = RealFs::new(&dir).unwrap();
        let elems = 4096usize; // 16 KiB block
        let plan = StridePlan::new(elems, 256).unwrap();
        let input: Vec<f32> = (0..elems).map(|i| (i % 89) as f32).collect();
        let mut raw = vec![0u8; elems * 4];
        to_bytes(&input, &mut raw);
        vfs.write(Path::new("in.dat"), &raw).unwrap();

        let bump = |_k: usize, chunk: &mut [f32]| {
            for v in chunk.iter_mut() {
                *v += 1.0;
            }
            Ok(())
        };
        let streamed =
            crate::workload::stream_block(&vfs, Path::new("in.dat"), Path::new("out_s.dat"), &plan, bump)
                .unwrap();
        // a 2-page budget forces fault/evict churn across the block
        let cache = Arc::new(PageCache::new(1024, 2 * 1024));
        let mapped = mmap_block_step(
            &vfs,
            &cache,
            Path::new("in.dat"),
            Path::new("out_m.dat"),
            &plan,
            1024, // one-page write-back batches under the 2-page budget
            bump,
        )
        .unwrap();
        assert_eq!(streamed, mapped);
        assert_eq!(
            vfs.read(Path::new("out_s.dat")).unwrap(),
            vfs.read(Path::new("out_m.dat")).unwrap(),
            "mapped and streamed iterations produce identical bytes"
        );
        let st = cache.stats();
        assert!(st.faults > 0, "mapped path faulted pages: {st:?}");
        assert!(
            st.peak_resident_bytes <= cache.budget(),
            "peak {} exceeds budget {}",
            st.peak_resident_bytes,
            cache.budget()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_read_back_streaming_respects_fd_budget() {
        // regression for the known limit: the no-read-back path used to
        // hold one fd open per iteration; with a budget of 4 the ceiling
        // must stay at budget + 1 (outputs + the group source) even for
        // 40 iterations
        let dir = std::env::temp_dir()
            .join(format!("sea_fdbudget_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let vfs = CountingVfs {
            inner: RealFs::new(&dir).unwrap(),
            open_now: Arc::new(AtomicUsize::new(0)),
            peak: Arc::new(AtomicUsize::new(0)),
        };
        // 64-element input block, 16-element strides, base value 5.0
        let elems = 64usize;
        let base = 5.0f32;
        let input = PathBuf::from("inputs/block.dat");
        let mut raw = vec![0u8; elems * 4];
        to_bytes(&vec![base; elems], &mut raw);
        vfs.write(&input, &raw).unwrap();

        let iterations = 40usize;
        let budget = 4usize;
        let outs: Vec<PathBuf> =
            (1..=iterations).map(|i| PathBuf::from(format!("out/iter{i:02}.dat"))).collect();
        let plan = StridePlan::new(elems, 16).unwrap();
        let br = AtomicU64::new(0);
        let bw = AtomicU64::new(0);
        stream_iteration_groups(
            &vfs,
            &input,
            &outs,
            &plan,
            budget,
            |_i, chunk| {
                for x in chunk.iter_mut() {
                    *x += 1.0;
                }
                Ok(())
            },
            &br,
            &bw,
        )
        .unwrap();

        let peak = vfs.peak.load(Ordering::Relaxed);
        assert!(peak <= budget + 1, "fd ceiling exceeded: peak {peak}");
        assert_eq!(vfs.open_now.load(Ordering::Relaxed), 0, "all handles closed");
        // every iteration file holds base + i across all strides
        for (idx, p) in outs.iter().enumerate() {
            let got = vfs.read(p).unwrap();
            assert_eq!(got.len(), elems * 4);
            for (e, quad) in got.chunks(4).enumerate() {
                let v = f32::from_le_bytes([quad[0], quad[1], quad[2], quad[3]]);
                assert_eq!(v, base + (idx + 1) as f32, "iter {} elem {e}", idx + 1);
            }
        }
        // group-boundary re-reads: 40 iterations / budget 4 = 10 sources
        assert_eq!(br.load(Ordering::Relaxed), (elems * 4 * 10) as u64);
        assert_eq!(bw.load(Ordering::Relaxed), (elems * 4 * iterations) as u64);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
