//! Simulated-experiment driver: one call = one point on a paper figure.
//!
//! Placement flows through the [`crate::placement::PlacementEngine`]
//! adapters ([`SeaPolicy`] over a `PaperEngine`, [`LustrePolicy`] over
//! the PFS-only baseline), so the simulator exercises the same policy
//! code path as the real-bytes VFS.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::error::Result;
use crate::hierarchy::SelectCfg;
use crate::placement::{FileTable, LustrePolicy, RuleSet, SeaPolicy};
use crate::sim::app::{AppProc, FlushDaemon, MgmtQueues, RunOutcome, SimPlacer};
use crate::sim::engine::Sim;
use crate::sim::spec::ClusterSpec;
use crate::sim::stack::{Stack, StackStats};
use crate::sim::topology::Location;
use crate::workload::IncrementationSpec;

/// Which system is under test (paper Figures 2–3).
#[derive(Debug, Clone)]
pub enum Mode {
    /// Baseline: all I/O to Lustre.
    Lustre,
    /// Sea, in-memory configuration: flush + evict only final-iteration
    /// files (§3.5.1).
    SeaInMemory,
    /// Sea, copy-all (flush-all): flush everything, evict nothing (§4.3).
    SeaCopyAll,
    /// Sea with custom rule lists.
    SeaCustom(RuleSet),
}

impl Mode {
    /// Display name for tables/plots.
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Lustre => "lustre",
            Mode::SeaInMemory => "sea-in-memory",
            Mode::SeaCopyAll => "sea-flush-all",
            Mode::SeaCustom(_) => "sea-custom",
        }
    }
}

/// One experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentCfg {
    /// Cluster under test.
    pub spec: ClusterSpec,
    /// Workload parameters.
    pub workload: IncrementationSpec,
    /// System under test.
    pub mode: Mode,
    /// PRNG seed (device shuffling).
    pub seed: u64,
}

/// Measured results of one run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Mode under test.
    pub mode: &'static str,
    /// Application makespan: when the last process finished, plus — for
    /// Sea modes — the flush-daemon tail (the paper's Fig 3 semantics).
    pub makespan: f64,
    /// When the last application process exited.
    pub app_done: f64,
    /// When the simulation fully quiesced (all writeback drained).
    pub quiescent: f64,
    /// Per-tier transfer statistics.
    pub stats: StackStats,
    /// Files flushed by the daemons.
    pub flushes: u64,
    /// Files evicted by the daemons.
    pub evictions: u64,
    /// Page-cache hit bytes (whole cluster).
    pub cache_hits: u64,
    /// Page-cache miss bytes.
    pub cache_misses: u64,
    /// Engine diagnostics: completed flows.
    pub flows: u64,
    /// Engine diagnostics: rate recomputations.
    pub recomputes: u64,
}

/// Run one simulated experiment.
pub fn run_experiment(cfg: &ExperimentCfg) -> Result<SimReport> {
    cfg.spec.validate()?;
    let table = Arc::new(FileTable::new());
    let programs = cfg.workload.build_programs(cfg.spec.nodes, cfg.spec.procs_per_node, &table);

    let mut sim = Sim::new();
    let stack = Stack::new(&mut sim, &cfg.spec);
    for &(f, size) in &programs.inputs {
        stack.register_file(f, size, Location::Lustre);
    }

    let placer: Rc<RefCell<dyn SimPlacer>> = match &cfg.mode {
        Mode::Lustre => Rc::new(RefCell::new(LustrePolicy::new())),
        sea_mode => {
            let rules = match sea_mode {
                Mode::SeaInMemory => RuleSet::in_memory(IncrementationSpec::final_glob()),
                Mode::SeaCopyAll => RuleSet::copy_all(),
                Mode::SeaCustom(r) => r.clone(),
                Mode::Lustre => unreachable!(),
            };
            let select = SelectCfg {
                max_file_size: cfg.workload.file_size,
                parallel_procs: cfg.spec.procs_per_node as u64,
            };
            Rc::new(RefCell::new(SeaPolicy::new(
                &cfg.spec, select, rules, table.clone(), cfg.seed,
            )))
        }
    };

    let mgmt = MgmtQueues::new(cfg.spec.nodes);
    let outcome = Rc::new(RefCell::new(RunOutcome::default()));
    for node in 0..cfg.spec.nodes {
        FlushDaemon::spawn(
            &mut sim,
            node,
            stack.clone(),
            mgmt.clone(),
            placer.clone(),
            outcome.clone(),
        );
    }
    for (k, prog) in programs.programs.into_iter().enumerate() {
        let node = k % cfg.spec.nodes;
        sim.spawn(Box::new(AppProc::new(
            node,
            prog,
            stack.clone(),
            placer.clone(),
            mgmt.clone(),
            outcome.clone(),
        )));
    }

    let quiescent = sim.run(1e12)?;
    debug_assert!(mgmt.drained(), "management queues must drain");
    debug_assert!(
        stack.state.borrow().writeback_drained(),
        "writeback must drain"
    );

    let out = outcome.borrow();
    let makespan = match cfg.mode {
        // paper semantics: Lustre's makespan is the job's wall time; the
        // writeback tail behind the page cache is bounded by the per-OST
        // dirty limit and not billed to the job
        Mode::Lustre => out.app_done,
        // Sea modes own their flush daemons, so their tail is billed
        _ => out.app_done.max(out.last_mgmt_done),
    };
    let (hits, misses) = {
        let st = stack.state.borrow();
        st.caches
            .iter()
            .fold((0u64, 0u64), |(h, m), c| (h + c.hits, m + c.misses))
    };
    Ok(SimReport {
        mode: cfg.mode.name(),
        makespan,
        app_done: out.app_done,
        quiescent,
        stats: stack.stats(),
        flushes: out.flushes,
        evictions: out.evictions,
        cache_hits: hits,
        cache_misses: misses,
        flows: sim.flows_completed,
        recomputes: sim.recomputes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{GIB, MIB};

    /// A scaled-down paper cluster that runs in milliseconds of host time.
    fn mini_spec() -> ClusterSpec {
        let mut s = ClusterSpec {
            nodes: 2,
            procs_per_node: 2,
            cores_per_node: 8,
            mem_bytes: 8 * GIB,
            tmpfs_bytes: 2 * GIB,
            disks_per_node: 2,
            disk_bytes: 20 * GIB,
            ..ClusterSpec::default()
        };
        s.lustre.oss_count = 2;
        s.lustre.osts_per_oss = 4;
        s
    }

    fn mini_workload(iters: usize) -> IncrementationSpec {
        IncrementationSpec {
            blocks: 24,
            file_size: 512 * MIB,
            iterations: iters,
            compute_per_iter: 0.0,
            read_back: true,
        }
    }

    fn run(mode: Mode, iters: usize) -> SimReport {
        run_experiment(&ExperimentCfg {
            spec: mini_spec(),
            workload: mini_workload(iters),
            mode,
            seed: 42,
        })
        .expect("experiment runs")
    }

    #[test]
    fn sea_in_memory_beats_lustre_with_intermediate_data() {
        let lustre = run(Mode::Lustre, 8);
        let sea = run(Mode::SeaInMemory, 8);
        let speedup = lustre.makespan / sea.makespan;
        assert!(
            speedup > 1.2,
            "sea {:.1}s vs lustre {:.1}s (speedup {speedup:.2})",
            sea.makespan,
            lustre.makespan
        );
    }

    #[test]
    fn sea_parity_at_single_iteration() {
        // paper §4.1: at 1 iteration Sea ≈ Lustre (all I/O is to Lustre
        // anyway... Sea still lands the single final write locally then
        // flushes it, so allow a modest band)
        let lustre = run(Mode::Lustre, 1);
        let sea = run(Mode::SeaInMemory, 1);
        let ratio = sea.makespan / lustre.makespan;
        assert!(
            (0.5..=1.6).contains(&ratio),
            "ratio {ratio:.2} (sea {:.1}s lustre {:.1}s)",
            sea.makespan,
            lustre.makespan
        );
    }

    #[test]
    fn flush_all_slower_than_in_memory() {
        // at this mini scale the flush daemon overlaps most of the copy
        // cost with the app, so the gap is modest; the paper-scale ratio
        // (Fig 3) is regenerated by bench_fig3/bigbrain_paper
        let im = run(Mode::SeaInMemory, 5);
        let fa = run(Mode::SeaCopyAll, 5);
        assert!(
            fa.makespan > im.makespan * 1.05,
            "flush-all {:.1}s vs in-memory {:.1}s",
            fa.makespan,
            im.makespan
        );
        assert!(fa.flushes > im.flushes);
    }

    #[test]
    fn lustre_mode_touches_no_local_tiers() {
        let r = run(Mode::Lustre, 3);
        assert!(r.stats.tiers.get("tmpfs").map_or(0, |t| t.written) == 0);
        assert!(r.stats.tiers.get("local disk").map_or(0, |t| t.written) == 0);
        assert!(r.stats.tiers["lustre"].read > 0);
        assert_eq!(r.flushes, 0);
    }

    #[test]
    fn in_memory_mode_flushes_only_final_files() {
        let r = run(Mode::SeaInMemory, 4);
        assert_eq!(r.flushes, 24, "one flush per block (final iteration)");
        assert_eq!(r.evictions, 24);
    }

    #[test]
    fn copy_all_flushes_every_iteration() {
        let r = run(Mode::SeaCopyAll, 4);
        assert_eq!(r.flushes, 24 * 4);
        assert_eq!(r.evictions, 0, "copy-all evicts nothing");
    }

    #[test]
    fn reports_are_internally_consistent() {
        let r = run(Mode::SeaInMemory, 4);
        assert!(r.app_done <= r.makespan + 1e-9);
        assert!(r.makespan <= r.quiescent + 1e-9);
        assert!(r.flows > 0 && r.recomputes > 0);
        let writes: u64 = r.stats.tiers.values().map(|t| t.written + t.cache_write).sum();
        assert!(writes > 0);
    }

    #[test]
    fn deterministic_across_seeds_for_lustre() {
        // Lustre mode has no randomness: identical reports
        let a = run(Mode::Lustre, 3);
        let b = run(Mode::Lustre, 3);
        assert_eq!(a.makespan, b.makespan);
    }
}
