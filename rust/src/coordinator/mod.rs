//! The Layer-3 coordinator: experiment drivers for the simulated cluster
//! and the real-bytes pipeline.
//!
//! * [`sim_driver`] — assembles simulator + policy + workload into one
//!   experiment run and extracts the paper's measured quantities
//!   (application makespan, per-tier transfer volumes, MDS load,
//!   cache behaviour, placement decisions).
//! * [`real_driver`] — leader/worker pipeline over OS threads: workers
//!   pull chunk tasks from a bounded queue (backpressure), do real file
//!   I/O through a [`crate::vfs`] mount, and run the per-iteration
//!   compute on the PJRT engine. This is the end-to-end path that proves
//!   the three layers compose (DESIGN.md §6).

pub mod real_driver;
pub mod sim_driver;

pub use sim_driver::{run_experiment, ExperimentCfg, Mode, SimReport};
pub use real_driver::{run_pipeline, IoMode, PipelineCfg, PipelineReport};
