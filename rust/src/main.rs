//! `sea` binary: CLI entry point for the Sea reproduction.
//!
//! See `sea --help` (module [`sea::cli`]) for subcommands: real pipeline
//! runs, paper-scale simulations, analytic model evaluation, device
//! benchmarks and dataset generation.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match sea::cli::run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("sea: error: {e}");
            std::process::exit(1);
        }
    }
}
