//! Cluster description for the simulator.
//!
//! Defaults replicate the paper's testbed (§3.5.2) calibrated with the
//! Table 2 `dd` bandwidths:
//!
//! * 8 compute nodes — 2× Xeon 6130 (32 cores), 250 GiB RAM of which
//!   126 GiB tmpfs, 6× 447 GiB SATA SSDs, 25 GbE.
//! * Lustre — 4 OSS × 11 HDD OSTs (10 TB each), 1 MDS; client dirty
//!   pages limited to 1 GB per OST.
//! * Table 2: tmpfs 6676/2560 MiB/s (r/w), local disk 501.7/426 MiB/s,
//!   Lustre 1381/121 MiB/s per stream, cached reads ≈ 6.2 GiB/s.

use crate::util::{GIB, MIB};

/// Lustre server-side description.
#[derive(Debug, Clone)]
pub struct LustreSpec {
    /// Number of object storage servers (data nodes).
    pub oss_count: usize,
    /// OSTs (disks) per OSS.
    pub osts_per_oss: usize,
    /// Per-OST capacity in bytes.
    pub ost_bytes: u64,
    /// Per-OST read bandwidth (bytes/s) as seen by one stream (Table 2).
    pub ost_read_bw: f64,
    /// Per-OST write bandwidth (bytes/s) as seen by one stream (Table 2).
    pub ost_write_bw: f64,
    /// OSS network bandwidth (bytes/s), per server.
    pub server_nic_bw: f64,
    /// MDS throughput in metadata ops/second (processor-sharing service).
    pub mds_ops_per_sec: f64,
    /// Minimum latency of a single metadata op (seconds) — the per-op
    /// rate cap; queueing delays emerge on top of this.
    pub mds_op_latency: f64,
    /// Metadata ops charged per file open/create/stat.
    pub mds_ops_per_open: f64,
    /// Extra metadata/lock ops charged per MiB written (lock grants,
    /// grant shrinking). This is what makes Lustre fall off its
    /// bandwidth-only model at very high process counts (paper Fig 2d).
    pub mds_ops_per_mib_written: f64,
    /// Client-side dirty-page limit per OST (bytes) — Lustre's
    /// `max_dirty_mb`, 1 GB in the paper's testbed.
    pub client_dirty_per_ost: u64,
    /// Lock-contention factor: grant/revoke ops per written MiB grow as
    /// `1 + alpha · (concurrent_lustre_writers − 1)`. This is the effect
    /// the paper's Fig 2d identifies ("too many incoming requests to the
    /// [metadata] server at 30+ parallel processes") that its
    /// bandwidth-only model cannot capture.
    pub mds_contention_alpha: f64,
}

impl Default for LustreSpec {
    fn default() -> Self {
        LustreSpec {
            oss_count: 4,
            osts_per_oss: 11,
            ost_bytes: 10_000 * GIB, // 10 TB nominal
            ost_read_bw: 1381.14 * MIB as f64,
            ost_write_bw: 121.0 * MIB as f64,
            server_nic_bw: 25.0e9 / 8.0, // 25 GbE
            mds_ops_per_sec: 4000.0,
            mds_op_latency: 1.0e-3,
            mds_ops_per_open: 1.0,
            mds_ops_per_mib_written: 0.08,
            client_dirty_per_ost: GIB,
            mds_contention_alpha: 0.03,
        }
    }
}

impl LustreSpec {
    /// Total OST count.
    pub fn ost_count(&self) -> usize {
        self.oss_count * self.osts_per_oss
    }
}

/// Whole-cluster description (compute nodes + Lustre + page cache knobs).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Compute nodes used by the experiment.
    pub nodes: usize,
    /// Application processes per node.
    pub procs_per_node: usize,
    /// CPU cores per node (compute flows are capped at 1 core each).
    pub cores_per_node: usize,
    /// Total RAM per node (bytes).
    pub mem_bytes: u64,
    /// tmpfs capacity per node (bytes) — carved out of RAM.
    pub tmpfs_bytes: u64,
    /// Memory-bus read bandwidth per node (bytes/s) — page-cache and
    /// tmpfs reads (Table 2 "cached read").
    pub mem_read_bw: f64,
    /// Memory-bus write bandwidth per node (bytes/s) — page-cache and
    /// tmpfs writes (Table 2 tmpfs write).
    pub mem_write_bw: f64,
    /// Local disks per node available to Sea.
    pub disks_per_node: usize,
    /// Per-disk capacity (bytes).
    pub disk_bytes: u64,
    /// Per-disk read bandwidth (bytes/s).
    pub disk_read_bw: f64,
    /// Per-disk write bandwidth (bytes/s).
    pub disk_write_bw: f64,
    /// Node NIC bandwidth (bytes/s), full duplex (separate in/out lanes).
    pub nic_bw: f64,
    /// Fraction of RAM allowed dirty before writers are throttled to
    /// device speed (Linux `vm.dirty_ratio`).
    pub dirty_ratio: f64,
    /// Fraction of RAM usable as page cache (rest is anonymous memory).
    pub cacheable_ratio: f64,
    /// Concurrent transfers of the per-node flush-and-evict daemon.
    /// One daemon process per node (paper §5.1) with async copies; a
    /// single 121 MiB/s stream per node cannot reproduce the paper's
    /// flush-all/Lustre ratio of 1.3x.
    pub flush_parallelism: usize,
    /// Lustre back end.
    pub lustre: LustreSpec,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            nodes: 5,
            procs_per_node: 6,
            cores_per_node: 32,
            mem_bytes: 250 * GIB,
            tmpfs_bytes: 126 * GIB,
            mem_read_bw: 6318.08 * MIB as f64,
            mem_write_bw: 2560.0 * MIB as f64,
            disks_per_node: 6,
            disk_bytes: 447 * GIB,
            disk_read_bw: 501.70 * MIB as f64,
            disk_write_bw: 426.0 * MIB as f64,
            nic_bw: 25.0e9 / 8.0,
            dirty_ratio: 0.20,
            cacheable_ratio: 0.85,
            flush_parallelism: 8,
            lustre: LustreSpec::default(),
        }
    }
}

impl ClusterSpec {
    /// The paper's fixed experimental conditions (§3.5.1): 5 nodes,
    /// 6 processes, 6 disks (10 iterations, 1000 blocks set by workload).
    pub fn paper_default() -> Self {
        Self::default()
    }

    /// Total application processes.
    pub fn total_procs(&self) -> usize {
        self.nodes * self.procs_per_node
    }

    /// Page-cache capacity per node (bytes).
    pub fn cache_bytes(&self) -> u64 {
        // tmpfs consumption is charged against the cache dynamically by
        // the page-cache model; here we expose the static ceiling.
        (self.mem_bytes as f64 * self.cacheable_ratio) as u64
    }

    /// Dirty-bytes throttle threshold per node.
    pub fn dirty_limit(&self) -> u64 {
        (self.mem_bytes as f64 * self.dirty_ratio) as u64
    }

    /// Validate structural sanity (used by config loading and tests).
    pub fn validate(&self) -> crate::error::Result<()> {
        use crate::error::Error;
        if self.nodes == 0 || self.procs_per_node == 0 || self.cores_per_node == 0 {
            return Err(Error::Config("nodes/procs/cores must be positive".into()));
        }
        if self.tmpfs_bytes > self.mem_bytes {
            return Err(Error::Config("tmpfs larger than RAM".into()));
        }
        if !(0.0..=1.0).contains(&self.dirty_ratio)
            || !(0.0..=1.0).contains(&self.cacheable_ratio)
        {
            return Err(Error::Config("ratios must be in [0,1]".into()));
        }
        for (name, bw) in [
            ("mem_read_bw", self.mem_read_bw),
            ("mem_write_bw", self.mem_write_bw),
            ("disk_read_bw", self.disk_read_bw),
            ("disk_write_bw", self.disk_write_bw),
            ("nic_bw", self.nic_bw),
            ("ost_read_bw", self.lustre.ost_read_bw),
            ("ost_write_bw", self.lustre.ost_write_bw),
            ("server_nic_bw", self.lustre.server_nic_bw),
            ("mds_ops_per_sec", self.lustre.mds_ops_per_sec),
        ] {
            if bw <= 0.0 {
                return Err(Error::Config(format!("{name} must be positive")));
            }
        }
        if self.lustre.oss_count == 0 || self.lustre.osts_per_oss == 0 {
            return Err(Error::Config("lustre needs at least one OSS/OST".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_are_table2() {
        let s = ClusterSpec::paper_default();
        assert_eq!(s.nodes, 5);
        assert_eq!(s.procs_per_node, 6);
        assert_eq!(s.disks_per_node, 6);
        assert_eq!(s.lustre.ost_count(), 44);
        assert!((s.disk_write_bw / MIB as f64 - 426.0).abs() < 1e-9);
        assert!((s.lustre.ost_write_bw / MIB as f64 - 121.0).abs() < 1e-9);
        s.validate().unwrap();
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut s = ClusterSpec::default();
        s.nodes = 0;
        assert!(s.validate().is_err());

        let mut s = ClusterSpec::default();
        s.tmpfs_bytes = s.mem_bytes + 1;
        assert!(s.validate().is_err());

        let mut s = ClusterSpec::default();
        s.dirty_ratio = 1.5;
        assert!(s.validate().is_err());

        let mut s = ClusterSpec::default();
        s.lustre.ost_write_bw = 0.0;
        assert!(s.validate().is_err());
    }
}
