//! The simulated storage stack: files, page cache, devices, Lustre, and
//! asynchronous writeback — everything between a process's `read`/`write`
//! and the engine's rated flows.
//!
//! Ops are executed by small *op processes* spawned per request; the
//! requesting process blocks until the op process notifies it. All shared
//! state lives in [`StackState`] behind an `Rc<RefCell<..>>` (the engine
//! is single-threaded).
//!
//! Semantics (paper §2.3, §3.4):
//!
//! * **Lustre read**: MDS op (processor-sharing service), then cached
//!   bytes at memory speed + missed bytes over OST→OSS-NIC→client-NIC,
//!   populating the reader's page cache.
//! * **Lustre write**: MDS open + per-MiB grant ops, then absorption into
//!   the client page cache (bounded by `vm.dirty_ratio` *and* the per-OST
//!   client dirty limit) at memory speed; the remainder throttles through
//!   at device speed. Dirty pages drain via per-node writeback daemons.
//! * **Local-disk write**: same, minus MDS and per-OST limits.
//! * **tmpfs**: memory-speed read/write; consumes RAM, which *pressures*
//!   the page cache (`PageCache::set_pressure`).
//! * **compute**: a flow through the node's CPU pool, capped at one core.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::sim::engine::{ProcId, Process, Sim, Step};
use crate::sim::pagecache::PageCache;
use crate::sim::spec::ClusterSpec;
use crate::sim::topology::{Location, Topology};
use crate::util::MIB;

/// Interned file identifier (assigned by the workload/placement layer).
pub type FileId = u64;

/// Registry record for one file.
#[derive(Debug, Clone)]
pub struct FileMeta {
    /// Current size in bytes.
    pub size: u64,
    /// Where the primary copy lives.
    pub loc: Location,
    /// Assigned OST (files with Lustre presence; round-robin on first
    /// placement).
    pub ost: Option<usize>,
    /// A flushed copy also exists on Lustre (Sea's *Copy* mode).
    pub lustre_replica: bool,
}

/// Writeback target: one backing device reachable from a node's cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WbTarget {
    /// Local disk `disk` of the daemon's node.
    Disk { disk: usize },
    /// A Lustre OST (global index).
    Ost { ost: usize },
}

/// Per-tier transfer statistics (bytes), for reports and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierBytes {
    /// Bytes read from the device (cache misses / direct).
    pub read: u64,
    /// Bytes written to the device (throttled + writeback).
    pub written: u64,
    /// Bytes served from page cache on reads.
    pub cache_read: u64,
    /// Bytes absorbed by page cache on writes.
    pub cache_write: u64,
}

/// Statistics per tier name.
#[derive(Debug, Clone, Default)]
pub struct StackStats {
    /// Keyed by `Location::tier_name()`.
    pub tiers: HashMap<&'static str, TierBytes>,
    /// Total MDS ops issued.
    pub mds_ops: f64,
}

impl StackStats {
    fn tier(&mut self, name: &'static str) -> &mut TierBytes {
        self.tiers.entry(name).or_default()
    }
}

/// Shared mutable simulator-side state.
pub struct StackState {
    /// Cluster description.
    pub spec: ClusterSpec,
    /// Engine resource handles.
    pub topo: Topology,
    /// File registry.
    pub files: HashMap<FileId, FileMeta>,
    /// Per-node page caches.
    pub caches: Vec<PageCache>,
    /// Per-node tmpfs bytes in use.
    pub tmpfs_used: Vec<u64>,
    /// Per-node, per-target queues of (file, bytes) awaiting writeback.
    wb_queues: Vec<BTreeMap<WbTarget, VecDeque<(FileId, u64)>>>,
    /// Per-node total queued writeback bytes (fast emptiness check).
    wb_pending: Vec<u64>,
    /// Per-(node, ost) client dirty bytes (Lustre `max_dirty_mb` model).
    dirty_per_ost: Vec<BTreeMap<usize, u64>>,
    /// Writeback daemon pids, one per node (spawned by `Stack::new`).
    wb_daemons: Vec<ProcId>,
    /// Next OST for round-robin assignment.
    next_ost: usize,
    /// Concurrent Lustre write ops (drives MDS lock contention).
    pub lustre_writers: u32,
    /// Transfer statistics.
    pub stats: StackStats,
}

impl StackState {
    /// Round-robin OST assignment (one OST per file, paper §3.4).
    pub fn assign_ost(&mut self) -> usize {
        let ost = self.next_ost;
        self.next_ost = (self.next_ost + 1) % self.spec.lustre.ost_count();
        ost
    }

    fn queue_writeback(&mut self, node: usize, target: WbTarget, file: FileId, bytes: u64) {
        if bytes == 0 {
            return;
        }
        self.wb_queues[node].entry(target).or_default().push_back((file, bytes));
        self.wb_pending[node] += bytes;
        if let WbTarget::Ost { ost } = target {
            *self.dirty_per_ost[node].entry(ost).or_default() += bytes;
        }
    }

    /// Remove queued writeback work for an unlinked file. Returns bytes
    /// cancelled.
    fn cancel_writeback(&mut self, node: usize, file: FileId) -> u64 {
        let mut cancelled = 0;
        for (target, q) in self.wb_queues[node].iter_mut() {
            let mut kept = VecDeque::with_capacity(q.len());
            for (f, b) in q.drain(..) {
                if f == file {
                    cancelled += b;
                    if let WbTarget::Ost { ost } = *target {
                        if let Some(d) = self.dirty_per_ost[node].get_mut(&ost) {
                            *d = d.saturating_sub(b);
                        }
                    }
                } else {
                    kept.push_back((f, b));
                }
            }
            *q = kept;
        }
        self.wb_pending[node] = self.wb_pending[node].saturating_sub(cancelled);
        cancelled
    }

    /// Per-OST client dirty room on `node` for `ost`.
    fn ost_dirty_room(&self, node: usize, ost: usize) -> u64 {
        let used = self.dirty_per_ost[node].get(&ost).copied().unwrap_or(0);
        self.spec.lustre.client_dirty_per_ost.saturating_sub(used)
    }

    /// Is all writeback drained everywhere (quiescence check)?
    pub fn writeback_drained(&self) -> bool {
        self.wb_pending.iter().all(|&b| b == 0)
    }
}

/// Handle to the shared stack; cheap to clone.
#[derive(Clone)]
pub struct Stack {
    /// Shared state (single-threaded engine ⇒ `Rc<RefCell>`).
    pub state: Rc<RefCell<StackState>>,
}

impl Stack {
    /// Build topology + caches inside `sim` and spawn writeback daemons.
    pub fn new(sim: &mut Sim, spec: &ClusterSpec) -> Stack {
        let topo = Topology::build(sim, spec);
        let caches = (0..spec.nodes)
            .map(|_| PageCache::new(spec.cache_bytes(), spec.dirty_limit()))
            .collect();
        let state = Rc::new(RefCell::new(StackState {
            spec: spec.clone(),
            topo,
            files: HashMap::new(),
            caches,
            tmpfs_used: vec![0; spec.nodes],
            wb_queues: (0..spec.nodes).map(|_| BTreeMap::new()).collect(),
            wb_pending: vec![0; spec.nodes],
            dirty_per_ost: (0..spec.nodes).map(|_| BTreeMap::new()).collect(),
            wb_daemons: Vec::new(),
            next_ost: 0,
            lustre_writers: 0,
            stats: StackStats::default(),
        }));
        let stack = Stack { state };
        for node in 0..spec.nodes {
            let pid = sim.spawn(Box::new(WritebackDaemon {
                node,
                stack: stack.clone(),
                inflight: Vec::new(),
            }));
            stack.state.borrow_mut().wb_daemons.push(pid);
        }
        stack
    }

    /// Register a file that already exists at `loc` with `size` bytes
    /// (e.g. the input dataset on Lustre). Assigns an OST for Lustre.
    pub fn register_file(&self, file: FileId, size: u64, loc: Location) {
        let mut st = self.state.borrow_mut();
        let ost = match loc {
            Location::Lustre => Some(st.assign_ost()),
            _ => None,
        };
        if let Location::Tmpfs { node } = loc {
            st.tmpfs_used[node] += size;
            let used = st.tmpfs_used[node];
            st.caches[node].set_pressure(used);
        }
        st.files.insert(file, FileMeta { size, loc, ost, lustre_replica: false });
    }

    /// Current metadata of a file.
    pub fn file_meta(&self, file: FileId) -> Option<FileMeta> {
        self.state.borrow().files.get(&file).cloned()
    }

    /// Spawn a read op for `file` from `node`; wakes `waker` when done.
    pub fn read(&self, sim: &mut Sim, node: usize, file: FileId, waker: ProcId) -> Result<()> {
        let meta = self
            .file_meta(file)
            .ok_or_else(|| Error::Sim(format!("read of unknown file {file}")))?;
        if !matches!(meta.loc, Location::Lustre) && !meta.loc.on_node(node) {
            return Err(Error::Sim(format!(
                "cross-node read: file {file} at {:?} from node {node}",
                meta.loc
            )));
        }
        let op = ReadOp { node, file, waker, stack: self.clone(), phase: 0, miss: 0 };
        let pid = sim.spawn(Box::new(op));
        let _ = pid;
        Ok(())
    }

    /// Spawn a write op creating/overwriting `file` (`size` bytes) at
    /// `loc` from `node`; wakes `waker` when done.
    pub fn write(
        &self,
        sim: &mut Sim,
        node: usize,
        file: FileId,
        size: u64,
        loc: Location,
        waker: ProcId,
    ) -> Result<()> {
        if !matches!(loc, Location::Lustre) && !loc.on_node(node) {
            return Err(Error::Sim(format!(
                "cross-node write: {loc:?} from node {node}"
            )));
        }
        {
            // registry update happens at op start: subsequent readers see
            // the new location; their reads contend with our flows just
            // as concurrent POSIX I/O would.
            let mut st = self.state.borrow_mut();
            let ost = match loc {
                Location::Lustre => {
                    let existing = st.files.get(&file).and_then(|m| m.ost);
                    Some(match existing {
                        Some(o) => o,
                        None => st.assign_ost(),
                    })
                }
                _ => None,
            };
            if let Location::Tmpfs { node: tn } = loc {
                st.tmpfs_used[tn] += size;
                let used = st.tmpfs_used[tn];
                st.caches[tn].set_pressure(used);
            }
            st.files.insert(file, FileMeta { size, loc, ost, lustre_replica: false });
        }
        let op = WriteOp {
            node,
            file,
            size,
            loc,
            waker,
            stack: self.clone(),
            phase: 0,
            through: 0,
            replica: false,
        };
        sim.spawn(Box::new(op));
        Ok(())
    }

    /// Spawn a compute burst of `seconds` CPU-seconds on `node`.
    pub fn compute(&self, sim: &mut Sim, node: usize, seconds: f64, waker: ProcId) {
        let cpu = self.state.borrow().topo.nodes[node].cpu;
        sim.start_flow(vec![cpu], seconds, 1.0, Some(waker));
    }

    /// Delete a file: drop cache residency, cancel queued writeback, free
    /// tmpfs space. Charged as one MDS op for Lustre files; local deletes
    /// are instantaneous (waker is still queued via a zero-length flow).
    pub fn delete(&self, sim: &mut Sim, node: usize, file: FileId, waker: ProcId) -> Result<()> {
        let (mds_ops, mds) = {
            let mut st = self.state.borrow_mut();
            let meta = st
                .files
                .remove(&file)
                .ok_or_else(|| Error::Sim(format!("delete of unknown file {file}")))?;
            match meta.loc {
                Location::Tmpfs { node: tn } => {
                    st.tmpfs_used[tn] = st.tmpfs_used[tn].saturating_sub(meta.size);
                    let used = st.tmpfs_used[tn];
                    st.caches[tn].set_pressure(used);
                }
                Location::Disk { node: dn, .. } => {
                    st.caches[dn].unlink(file);
                    st.cancel_writeback(dn, file);
                }
                Location::Lustre => {
                    st.caches[node].unlink(file);
                    st.cancel_writeback(node, file);
                }
            }
            let ops = if matches!(meta.loc, Location::Lustre) {
                st.stats.mds_ops += st.spec.lustre.mds_ops_per_open;
                st.spec.lustre.mds_ops_per_open
            } else {
                0.0
            };
            (ops, st.topo.mds)
        };
        let latency_cap = 1.0 / self.state.borrow().spec.lustre.mds_op_latency;
        sim.start_flow(vec![mds], mds_ops, latency_cap, Some(waker));
        Ok(())
    }

    /// Spawn a *flush* op: copy `file` (currently node-local) to Lustre,
    /// then optionally evict the local copy (Sea's Copy / Move modes,
    /// Table 1). Wakes `waker` when the copy (and eviction) is complete.
    ///
    /// The local copy remains the registry's primary during the copy;
    /// on completion either `lustre_replica` is set (Copy) or the
    /// primary moves to Lustre (Move).
    pub fn flush(
        &self,
        sim: &mut Sim,
        node: usize,
        file: FileId,
        evict_after: bool,
        waker: ProcId,
    ) -> Result<()> {
        let meta = self
            .file_meta(file)
            .ok_or_else(|| Error::Sim(format!("flush of unknown file {file}")))?;
        if matches!(meta.loc, Location::Lustre) {
            // already on Lustre: nothing to copy
            sim.notify(waker);
            return Ok(());
        }
        if !meta.loc.on_node(node) {
            return Err(Error::Sim(format!(
                "flush from wrong node: file {file} at {:?}, daemon on {node}",
                meta.loc
            )));
        }
        sim.spawn(Box::new(FlushOp {
            node,
            file,
            evict_after,
            waker,
            stack: self.clone(),
            phase: 0,
        }));
        Ok(())
    }

    /// Drop the local copy of a file whose primary (or replica) is on
    /// Lustre; the file's primary becomes Lustre. Errors if no Lustre
    /// copy exists (would lose data). Returns the freed local location.
    pub fn evict_local(&self, file: FileId) -> Result<Location> {
        let mut st = self.state.borrow_mut();
        let meta = st
            .files
            .get(&file)
            .cloned()
            .ok_or_else(|| Error::Sim(format!("evict of unknown file {file}")))?;
        let local = match meta.loc {
            Location::Lustre => {
                return Err(Error::Sim(format!("file {file} has no local copy")))
            }
            loc => loc,
        };
        if !meta.lustre_replica {
            return Err(Error::Sim(format!(
                "refusing to evict file {file}: no Lustre copy (would lose data)"
            )));
        }
        match local {
            Location::Tmpfs { node } => {
                st.tmpfs_used[node] = st.tmpfs_used[node].saturating_sub(meta.size);
                let used = st.tmpfs_used[node];
                st.caches[node].set_pressure(used);
            }
            Location::Disk { node, .. } => {
                st.caches[node].unlink(file);
                st.cancel_writeback(node, file);
            }
            Location::Lustre => unreachable!(),
        }
        let m = st.files.get_mut(&file).expect("checked");
        m.loc = Location::Lustre;
        m.lustre_replica = false;
        Ok(local)
    }

    /// Wake `node`'s writeback daemon (new dirty work queued).
    fn kick_writeback(&self, sim: &mut Sim, node: usize) {
        let pid = self.state.borrow().wb_daemons[node];
        sim.notify(pid);
    }

    /// Tier statistics snapshot.
    pub fn stats(&self) -> StackStats {
        self.state.borrow().stats.clone()
    }
}

// --- read op ---------------------------------------------------------------

struct ReadOp {
    node: usize,
    file: FileId,
    waker: ProcId,
    stack: Stack,
    phase: u8,
    miss: u64,
}

impl Process for ReadOp {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        loop {
            match self.phase {
                // phase 0: MDS for Lustre, else skip ahead
                0 => {
                    self.phase = 1;
                    let st = self.stack.state.borrow();
                    let meta = match st.files.get(&self.file) {
                        Some(m) => m.clone(),
                        None => {
                            drop(st);
                            // file vanished: wake requester, abort
                            sim.notify(self.waker);
                            return Step::Done;
                        }
                    };
                    if matches!(meta.loc, Location::Lustre) {
                        let ops = st.spec.lustre.mds_ops_per_open;
                        let cap = 1.0 / st.spec.lustre.mds_op_latency;
                        let mds = st.topo.mds;
                        drop(st);
                        self.stack.state.borrow_mut().stats.mds_ops += ops;
                        sim.start_flow(vec![mds], ops, cap, Some(pid));
                        return Step::Waiting;
                    }
                }
                // phase 1: cached portion at memory speed
                1 => {
                    self.phase = 2;
                    let mut st = self.stack.state.borrow_mut();
                    let meta = match st.files.get(&self.file) {
                        Some(m) => m.clone(),
                        None => {
                            sim.notify(self.waker);
                            return Step::Done;
                        }
                    };
                    // tmpfs never goes through the page cache split: it
                    // IS memory
                    let (hit, miss) = match meta.loc {
                        Location::Tmpfs { .. } => (meta.size, 0),
                        _ => st.caches[self.node].read_split(self.file, meta.size),
                    };
                    self.miss = miss;
                    let tier = meta.loc.tier_name();
                    st.stats.tier(tier).cache_read += match meta.loc {
                        Location::Tmpfs { .. } => 0,
                        _ => hit,
                    };
                    if matches!(meta.loc, Location::Tmpfs { .. }) {
                        st.stats.tier(tier).read += hit;
                    }
                    let path = st.topo.cache_read_path(self.node);
                    drop(st);
                    if hit > 0 {
                        sim.start_flow(path, hit as f64, f64::INFINITY, Some(pid));
                        return Step::Waiting;
                    }
                }
                // phase 2: missed portion from the device
                2 => {
                    self.phase = 3;
                    if self.miss > 0 {
                        let mut st = self.stack.state.borrow_mut();
                        let meta = match st.files.get(&self.file) {
                            Some(m) => m.clone(),
                            None => {
                                sim.notify(self.waker);
                                return Step::Done;
                            }
                        };
                        let path = match meta.loc {
                            Location::Lustre => {
                                let ost = meta.ost.expect("lustre file has ost");
                                st.topo.lustre_read_path(self.node, ost)
                            }
                            loc => st.topo.local_read_path(loc),
                        };
                        st.stats.tier(meta.loc.tier_name()).read += self.miss;
                        drop(st);
                        sim.start_flow(path, self.miss as f64, f64::INFINITY, Some(pid));
                        return Step::Waiting;
                    }
                }
                // phase 3: populate cache with missed bytes, wake caller
                _ => {
                    if self.miss > 0 {
                        let mut st = self.stack.state.borrow_mut();
                        if st.files.contains_key(&self.file) {
                            let node = self.node;
                            let file = self.file;
                            let miss = self.miss;
                            st.caches[node].insert_clean(file, miss);
                        }
                    }
                    sim.notify(self.waker);
                    return Step::Done;
                }
            }
        }
    }
}

// --- write op --------------------------------------------------------------

struct WriteOp {
    node: usize,
    file: FileId,
    size: u64,
    loc: Location,
    waker: ProcId,
    stack: Stack,
    phase: u8,
    through: u64,
    /// Replica write (flush): on completion mark `lustre_replica` instead
    /// of having re-registered the primary at op start.
    replica: bool,
}

impl Process for WriteOp {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        loop {
            match self.phase {
                // phase 0: MDS open + per-MiB grant ops for Lustre
                0 => {
                    self.phase = 1;
                    if matches!(self.loc, Location::Lustre) {
                        let mut st = self.stack.state.borrow_mut();
                        // lock contention: grant traffic grows with the
                        // number of concurrent writers (paper Fig 2d)
                        st.lustre_writers += 1;
                        let contention = 1.0
                            + st.spec.lustre.mds_contention_alpha
                                * (st.lustre_writers.saturating_sub(1)) as f64;
                        let ops = st.spec.lustre.mds_ops_per_open
                            + st.spec.lustre.mds_ops_per_mib_written
                                * contention
                                * (self.size as f64 / MIB as f64);
                        st.stats.mds_ops += ops;
                        // open is serial; grants pipeline moderately
                        let cap = 8.0 / st.spec.lustre.mds_op_latency;
                        let mds = st.topo.mds;
                        drop(st);
                        sim.start_flow(vec![mds], ops, cap, Some(pid));
                        return Step::Waiting;
                    }
                }
                // phase 1: tmpfs fast path / cache absorption
                1 => {
                    self.phase = 2;
                    if self.replica {
                        // flush copies stream straight to Lustre: the
                        // flush is only *complete* when the bytes are
                        // materialized on the PFS (paper §4.3 — flush-all
                        // must wait for the actual transfer), so replica
                        // writes bypass page-cache absorption entirely
                        self.through = self.size;
                        continue;
                    }
                    let mut st = self.stack.state.borrow_mut();
                    let tier = self.loc.tier_name();
                    match self.loc {
                        Location::Tmpfs { .. } => {
                            st.stats.tier(tier).written += self.size;
                            let path = st.topo.cache_write_path(self.node);
                            drop(st);
                            self.through = 0;
                            self.phase = 3; // no passthrough needed
                            sim.start_flow(path, self.size as f64, f64::INFINITY, Some(pid));
                            return Step::Waiting;
                        }
                        loc => {
                            let extra = match loc {
                                Location::Lustre => {
                                    let ost = st
                                        .files
                                        .get(&self.file)
                                        .and_then(|m| m.ost)
                                        .expect("ost assigned at write start");
                                    st.ost_dirty_room(self.node, ost)
                                }
                                _ => u64::MAX,
                            };
                            let absorbed =
                                st.caches[self.node].absorb_write(self.file, self.size, extra);
                            self.through = self.size - absorbed;
                            st.stats.tier(tier).cache_write += absorbed;
                            if absorbed > 0 {
                                let target = match loc {
                                    Location::Disk { disk, .. } => WbTarget::Disk { disk },
                                    Location::Lustre => WbTarget::Ost {
                                        ost: st.files.get(&self.file).and_then(|m| m.ost).unwrap(),
                                    },
                                    Location::Tmpfs { .. } => unreachable!(),
                                };
                                st.queue_writeback(self.node, target, self.file, absorbed);
                            }
                            let path = st.topo.cache_write_path(self.node);
                            drop(st);
                            if absorbed > 0 {
                                self.stack.kick_writeback(sim, self.node);
                                sim.start_flow(path, absorbed as f64, f64::INFINITY, Some(pid));
                                return Step::Waiting;
                            }
                        }
                    }
                }
                // phase 2: throttled passthrough at device speed
                2 => {
                    self.phase = 3;
                    if self.through > 0 {
                        let mut st = self.stack.state.borrow_mut();
                        let path = match self.loc {
                            Location::Lustre => {
                                let ost =
                                    st.files.get(&self.file).and_then(|m| m.ost).unwrap();
                                st.topo.lustre_write_path(self.node, ost)
                            }
                            loc => st.topo.local_write_path(loc),
                        };
                        st.stats.tier(self.loc.tier_name()).written += self.through;
                        drop(st);
                        sim.start_flow(path, self.through as f64, f64::INFINITY, Some(pid));
                        return Step::Waiting;
                    }
                }
                // phase 3: done
                _ => {
                    let mut st = self.stack.state.borrow_mut();
                    if matches!(self.loc, Location::Lustre) {
                        st.lustre_writers = st.lustre_writers.saturating_sub(1);
                    }
                    if self.replica {
                        if let Some(m) = st.files.get_mut(&self.file) {
                            m.lustre_replica = true;
                        }
                    }
                    drop(st);
                    sim.notify(self.waker);
                    return Step::Done;
                }
            }
        }
    }
}

// --- flush op (Sea Copy / Move, Table 1) -------------------------------------

struct FlushOp {
    node: usize,
    file: FileId,
    evict_after: bool,
    waker: ProcId,
    stack: Stack,
    phase: u8,
}

impl Process for FlushOp {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        match self.phase {
            // phase 0: read the local copy (cache-aware)
            0 => {
                self.phase = 1;
                if self.stack.read(sim, self.node, self.file, pid).is_err() {
                    // file vanished (e.g. deleted while queued): give up
                    sim.notify(self.waker);
                    return Step::Done;
                }
                Step::Waiting
            }
            // phase 1: write a Lustre replica
            1 => {
                self.phase = 2;
                let size = {
                    let mut st = self.stack.state.borrow_mut();
                    let meta = match st.files.get(&self.file).cloned() {
                        Some(m) => m,
                        None => {
                            sim.notify(self.waker);
                            return Step::Done;
                        }
                    };
                    if meta.ost.is_none() {
                        let ost = st.assign_ost();
                        st.files.get_mut(&self.file).expect("present").ost = Some(ost);
                    }
                    meta.size
                };
                sim.spawn(Box::new(WriteOp {
                    node: self.node,
                    file: self.file,
                    size,
                    loc: Location::Lustre,
                    waker: pid,
                    stack: self.stack.clone(),
                    phase: 0,
                    through: 0,
                    replica: true,
                }));
                Step::Waiting
            }
            // phase 2: optional eviction, then wake the requester
            _ => {
                if self.evict_after {
                    // best-effort: replica flag is set by the WriteOp
                    let _ = self.stack.evict_local(self.file);
                }
                sim.notify(self.waker);
                Step::Done
            }
        }
    }
}

// --- writeback daemon -------------------------------------------------------

/// Per-node background flusher with one batch in flight **per backing
/// device**, mirroring Linux's per-BDI flusher threads: a node can drain
/// all its disks and several OSTs concurrently. A single daemon process
/// multiplexes the batches by polling `flow_alive` on wake-up.
struct WritebackDaemon {
    node: usize,
    stack: Stack,
    /// In-flight batches: (flow, target, entries).
    inflight: Vec<(crate::sim::engine::FlowId, WbTarget, Vec<(FileId, u64)>)>,
}

/// Max bytes per writeback batch flow.
const WB_BATCH: u64 = 256 * MIB;

impl Process for WritebackDaemon {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        // complete finished batches
        let mut still = Vec::with_capacity(self.inflight.len());
        for (flow, target, entries) in self.inflight.drain(..) {
            if sim.flow_alive(flow) {
                still.push((flow, target, entries));
                continue;
            }
            let mut st = self.stack.state.borrow_mut();
            for &(file, bytes) in &entries {
                let node = self.node;
                st.caches[node].complete_writeback(file, bytes);
                if let WbTarget::Ost { ost } = target {
                    if let Some(d) = st.dirty_per_ost[node].get_mut(&ost) {
                        *d = d.saturating_sub(bytes);
                    }
                }
                st.wb_pending[node] = st.wb_pending[node].saturating_sub(bytes);
                let tier = match target {
                    WbTarget::Disk { .. } => "local disk",
                    WbTarget::Ost { .. } => "lustre",
                };
                st.stats.tier(tier).written += bytes;
            }
        }
        self.inflight = still;
        // start one batch for every queued target without an in-flight one
        let new_batches: Vec<(WbTarget, Vec<(FileId, u64)>, Vec<crate::sim::engine::ResourceId>, u64)> = {
            let mut st = self.stack.state.borrow_mut();
            let node = self.node;
            let busy: Vec<WbTarget> = self.inflight.iter().map(|(_, t, _)| *t).collect();
            let targets: Vec<WbTarget> = st.wb_queues[node]
                .iter()
                .filter(|(t, q)| !q.is_empty() && !busy.contains(t))
                .map(|(&t, _)| t)
                .collect();
            targets
                .into_iter()
                .map(|target| {
                    let q = st.wb_queues[node].get_mut(&target).expect("nonempty");
                    let mut batch = Vec::new();
                    let mut total = 0;
                    while total < WB_BATCH {
                        match q.pop_front() {
                            Some((f, b)) => {
                                let take = b.min(WB_BATCH - total);
                                if take < b {
                                    q.push_front((f, b - take));
                                }
                                total += take;
                                batch.push((f, take));
                            }
                            None => break,
                        }
                    }
                    let path = match target {
                        WbTarget::Disk { disk } => {
                            st.topo.local_write_path(Location::Disk { node, disk })
                        }
                        WbTarget::Ost { ost } => st.topo.lustre_write_path(node, ost),
                    };
                    (target, batch, path, total)
                })
                .collect()
        };
        for (target, batch, path, total) in new_batches {
            let flow = sim.start_flow(path, total as f64, f64::INFINITY, Some(pid));
            self.inflight.push((flow, target, batch));
        }
        Step::Waiting
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::GIB;

    /// Tiny driver process that runs a closure-defined script of ops.
    enum ScriptOp {
        Read(FileId),
        Write(FileId, u64, Location),
        Delete(FileId),
        Compute(f64),
    }
    struct Script {
        node: usize,
        ops: VecDeque<ScriptOp>,
        stack: Stack,
        waiting: bool,
        done_at: Rc<RefCell<f64>>,
    }
    impl Process for Script {
        fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
            self.waiting = false;
            match self.ops.pop_front() {
                None => {
                    *self.done_at.borrow_mut() = sim.now();
                    Step::Done
                }
                Some(op) => {
                    match op {
                        ScriptOp::Read(f) => self.stack.read(sim, self.node, f, pid).unwrap(),
                        ScriptOp::Write(f, s, l) => {
                            self.stack.write(sim, self.node, f, s, l, pid).unwrap()
                        }
                        ScriptOp::Delete(f) => {
                            self.stack.delete(sim, self.node, f, pid).unwrap()
                        }
                        ScriptOp::Compute(s) => self.stack.compute(sim, self.node, s, pid),
                    }
                    Step::Waiting
                }
            }
        }
    }

    fn run_script(spec: &ClusterSpec, preregister: &[(FileId, u64, Location)], ops: Vec<ScriptOp>) -> (f64, StackStats) {
        let mut sim = Sim::new();
        let stack = Stack::new(&mut sim, spec);
        for &(f, s, l) in preregister {
            stack.register_file(f, s, l);
        }
        let done = Rc::new(RefCell::new(-1.0));
        sim.spawn(Box::new(Script {
            node: 0,
            ops: ops.into(),
            stack: stack.clone(),
            waiting: false,
            done_at: done.clone(),
        }));
        sim.run(1e12).unwrap();
        let t = *done.borrow();
        assert!(t >= 0.0, "script did not finish");
        (t, stack.stats())
    }

    fn small_spec() -> ClusterSpec {
        // 1 node, simple numbers for hand-checkable results
        let mut s = ClusterSpec {
            nodes: 1,
            procs_per_node: 1,
            cores_per_node: 4,
            mem_bytes: 10 * GIB,
            tmpfs_bytes: 4 * GIB,
            mem_read_bw: 1000.0 * MIB as f64,
            mem_write_bw: 500.0 * MIB as f64,
            disks_per_node: 2,
            disk_bytes: 100 * GIB,
            disk_read_bw: 100.0 * MIB as f64,
            disk_write_bw: 50.0 * MIB as f64,
            nic_bw: 1000.0 * MIB as f64,
            dirty_ratio: 0.2,
            cacheable_ratio: 0.8,
            ..ClusterSpec::default()
        };
        s.lustre.ost_read_bw = 200.0 * MIB as f64;
        s.lustre.ost_write_bw = 20.0 * MIB as f64;
        s.lustre.server_nic_bw = 1000.0 * MIB as f64;
        s.lustre.mds_ops_per_sec = 1000.0;
        s.lustre.mds_op_latency = 1e-3;
        s.lustre.mds_ops_per_mib_written = 0.0;
        s
    }

    #[test]
    fn tmpfs_write_then_read_at_memory_speed() {
        let spec = small_spec();
        let f = 1;
        let sz = 500 * MIB;
        let (t, stats) = run_script(
            &spec,
            &[],
            vec![
                ScriptOp::Write(f, sz, Location::Tmpfs { node: 0 }),
                ScriptOp::Read(f),
            ],
        );
        // write at 500 MiB/s = 1.0s; read at 1000 MiB/s = 0.5s
        assert!((t - 1.5).abs() < 1e-6, "t = {t}");
        assert_eq!(stats.tiers["tmpfs"].written, sz);
        assert_eq!(stats.tiers["tmpfs"].read, sz);
    }

    #[test]
    fn lustre_cold_read_travels_network() {
        let spec = small_spec();
        let f = 7;
        let sz = 200 * MIB;
        let (t, stats) = run_script(&spec, &[(f, sz, Location::Lustre)], vec![ScriptOp::Read(f)]);
        // mds 1 op @1ms + 200 MiB at min(200, 1000, 1000) = 200 MiB/s = 1s
        assert!((t - 1.001).abs() < 1e-3, "t = {t}");
        assert_eq!(stats.tiers["lustre"].read, sz);
        assert!(stats.mds_ops >= 1.0);
    }

    #[test]
    fn second_lustre_read_hits_page_cache() {
        let spec = small_spec();
        let f = 7;
        let sz = 200 * MIB;
        let (t, stats) = run_script(
            &spec,
            &[(f, sz, Location::Lustre)],
            vec![ScriptOp::Read(f), ScriptOp::Read(f)],
        );
        // second read at mem_r 1000 MiB/s = 0.2s (+1ms mds)
        assert!((t - (1.001 + 0.2 + 0.001)).abs() < 5e-3, "t = {t}");
        assert_eq!(stats.tiers["lustre"].read, sz, "device read only once");
        assert_eq!(stats.tiers["lustre"].cache_read, sz);
    }

    #[test]
    fn disk_write_absorbed_by_cache_then_writeback() {
        let spec = small_spec();
        let f = 3;
        let sz = 100 * MIB; // well under dirty limit (2 GiB)
        let (t, stats) = run_script(
            &spec,
            &[],
            vec![ScriptOp::Write(f, sz, Location::Disk { node: 0, disk: 0 })],
        );
        // foreground completes at memory write speed: 100/500 = 0.2s
        assert!((t - 0.2).abs() < 1e-6, "t = {t}");
        assert_eq!(stats.tiers["local disk"].cache_write, sz);
        // but the sim runs until writeback drains: device sees the bytes
        assert_eq!(stats.tiers["local disk"].written, sz);
    }

    #[test]
    fn dirty_limit_throttles_big_writes() {
        let spec = small_spec(); // dirty limit = 2 GiB
        let f = 4;
        let sz = 4 * GIB;
        let (t, _stats) = run_script(
            &spec,
            &[],
            vec![ScriptOp::Write(f, sz, Location::Disk { node: 0, disk: 0 })],
        );
        // 2 GiB absorbed at 500 MiB/s (4.1s), 2 GiB through at ~50 MiB/s
        // (writeback contends on the same disk lane, so ≥ 40.96s)
        assert!(t > 30.0, "expected throttling, t = {t}");
    }

    #[test]
    fn per_ost_dirty_limit_binds_lustre_writes() {
        let mut spec = small_spec();
        spec.lustre.client_dirty_per_ost = 100 * MIB;
        let f = 5;
        let sz = 1000 * MIB;
        let (t, _) = run_script(&spec, &[], vec![ScriptOp::Write(f, sz, Location::Lustre)]);
        // only 100 MiB absorbed; 900 MiB at ~20 MiB/s ≥ 45s
        assert!(t > 40.0, "t = {t}");
    }

    #[test]
    fn compute_uses_cpu_pool() {
        let spec = small_spec();
        let (t, _) = run_script(&spec, &[], vec![ScriptOp::Compute(2.5)]);
        assert!((t - 2.5).abs() < 1e-9, "t = {t}");
    }

    #[test]
    fn delete_frees_tmpfs_and_cache() {
        let spec = small_spec();
        let f = 6;
        let (t, _) = run_script(
            &spec,
            &[],
            vec![
                ScriptOp::Write(f, GIB, Location::Tmpfs { node: 0 }),
                ScriptOp::Delete(f),
                ScriptOp::Write(f, GIB, Location::Tmpfs { node: 0 }),
            ],
        );
        assert!(t > 0.0);
    }

    #[test]
    fn read_unknown_file_errors() {
        let mut sim = Sim::new();
        let spec = small_spec();
        let stack = Stack::new(&mut sim, &spec);
        let pid = ProcId(999);
        assert!(stack.read(&mut sim, 0, 42, pid).is_err());
    }

    #[test]
    fn cross_node_access_rejected() {
        let mut spec = small_spec();
        spec.nodes = 2;
        let mut sim = Sim::new();
        let stack = Stack::new(&mut sim, &spec);
        stack.register_file(1, MIB, Location::Tmpfs { node: 1 });
        assert!(stack.read(&mut sim, 0, 1, ProcId(999)).is_err());
        assert!(stack
            .write(&mut sim, 0, 2, MIB, Location::Disk { node: 1, disk: 0 }, ProcId(999))
            .is_err());
    }
}
