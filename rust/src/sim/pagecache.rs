//! Per-node Linux page-cache model (byte-granular, per-file).
//!
//! Captures the behaviours the paper's §2.3/§3.4 identify as decisive:
//!
//! * reads of recently-accessed files are served from memory;
//! * writes complete at memory speed until the node's dirty limit
//!   (`vm.dirty_ratio`) is reached, then throttle to device speed;
//! * dirty pages are flushed asynchronously by per-device writeback;
//! * clean pages are evicted LRU; dirty pages are never dropped;
//! * tmpfs usage exerts *pressure*: it shrinks the usable cache.
//!
//! Granularity is bytes-per-file rather than 4 KiB pages: the workloads
//! here read/write whole 617 MiB blocks, so range tracking would add
//! state without changing any measured quantity.

use std::collections::HashMap;

/// Per-file cache residency.
#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    clean: u64,
    dirty: u64,
    /// LRU tick of the last touch.
    tick: u64,
}

/// One node's page cache.
#[derive(Debug)]
pub struct PageCache {
    cap_base: u64,
    dirty_limit: u64,
    pressure: u64,
    clean_total: u64,
    dirty_total: u64,
    files: HashMap<u64, Entry>,
    lru: u64,
    /// Cumulative bytes served from cache (hit accounting).
    pub hits: u64,
    /// Cumulative bytes that missed cache.
    pub misses: u64,
}

impl PageCache {
    /// New cache with `cap` usable bytes and `dirty_limit` throttle.
    pub fn new(cap: u64, dirty_limit: u64) -> PageCache {
        PageCache {
            cap_base: cap,
            dirty_limit,
            pressure: 0,
            clean_total: 0,
            dirty_total: 0,
            files: HashMap::new(),
            lru: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Usable capacity after external (tmpfs) pressure.
    pub fn effective_cap(&self) -> u64 {
        self.cap_base.saturating_sub(self.pressure)
    }

    /// Report external memory pressure (tmpfs bytes in use). Evicts clean
    /// pages if the cache now exceeds its shrunken capacity.
    pub fn set_pressure(&mut self, bytes: u64) {
        self.pressure = bytes;
        let cap = self.effective_cap();
        let used = self.clean_total + self.dirty_total;
        if used > cap {
            let need = used - cap;
            self.evict_clean(need);
        }
    }

    /// Total bytes of `file` resident (clean + dirty).
    pub fn cached(&self, file: u64) -> u64 {
        self.files.get(&file).map(|e| e.clean + e.dirty).unwrap_or(0)
    }

    /// Dirty bytes of `file`.
    pub fn dirty_of(&self, file: u64) -> u64 {
        self.files.get(&file).map(|e| e.dirty).unwrap_or(0)
    }

    /// Node-wide dirty bytes.
    pub fn dirty_total(&self) -> u64 {
        self.dirty_total
    }

    /// Node-wide resident bytes.
    pub fn resident_total(&self) -> u64 {
        self.clean_total + self.dirty_total
    }

    /// Room before the dirty throttle engages.
    pub fn dirty_room(&self) -> u64 {
        self.dirty_limit
            .min(self.effective_cap())
            .saturating_sub(self.dirty_total)
    }

    fn touch(&mut self, file: u64) {
        self.lru += 1;
        let t = self.lru;
        if let Some(e) = self.files.get_mut(&file) {
            e.tick = t;
        }
    }

    /// Split a read of `size` bytes into (from_cache, from_device) and
    /// account the hit/miss.
    pub fn read_split(&mut self, file: u64, size: u64) -> (u64, u64) {
        let c = self.cached(file).min(size);
        self.touch(file);
        self.hits += c;
        self.misses += size - c;
        (c, size - c)
    }

    /// Evict up to `need` clean bytes, LRU-first. Returns bytes evicted.
    pub fn evict_clean(&mut self, need: u64) -> u64 {
        let mut victims: Vec<(u64, u64, u64)> = self
            .files
            .iter()
            .filter(|(_, e)| e.clean > 0)
            .map(|(&f, e)| (e.tick, f, e.clean))
            .collect();
        victims.sort_unstable();
        let mut freed = 0;
        for (_, f, clean) in victims {
            if freed >= need {
                break;
            }
            let take = clean.min(need - freed);
            let e = self.files.get_mut(&f).expect("victim exists");
            e.clean -= take;
            self.clean_total -= take;
            freed += take;
            if e.clean == 0 && e.dirty == 0 {
                self.files.remove(&f);
            }
        }
        freed
    }

    /// Insert up to `size` CLEAN bytes of `file` (after a miss read or a
    /// completed writeback), evicting LRU clean pages as needed. Never
    /// displaces dirty pages. Returns bytes actually inserted.
    pub fn insert_clean(&mut self, file: u64, size: u64) -> u64 {
        let cap = self.effective_cap();
        let already = self.cached(file);
        let want = size.min(cap.saturating_sub(self.dirty_total).saturating_sub(already));
        if want == 0 {
            self.touch(file);
            return 0;
        }
        let free = cap.saturating_sub(self.clean_total + self.dirty_total);
        if free < want {
            self.evict_clean(want - free);
        }
        let free = cap.saturating_sub(self.clean_total + self.dirty_total);
        let ins = want.min(free);
        self.lru += 1;
        let t = self.lru;
        let e = self.files.entry(file).or_default();
        e.clean += ins;
        e.tick = t;
        self.clean_total += ins;
        ins
    }

    /// Absorb a write: up to `size` bytes become DIRTY cache content,
    /// bounded by the dirty throttle and by `extra_room` (e.g. Lustre's
    /// per-OST client dirty limit). Returns bytes absorbed; the caller
    /// writes the remainder through at device speed.
    pub fn absorb_write(&mut self, file: u64, size: u64, extra_room: u64) -> u64 {
        let room = self.dirty_room().min(extra_room);
        // writing dirties fresh pages; clean pages of the same file are
        // replaced first (overwrite), so free that double-count
        let want = size.min(room);
        if want == 0 {
            self.touch(file);
            return 0;
        }
        // make space: overwritten clean bytes of this file come back first
        let e = self.files.entry(file).or_default();
        let overwrite = e.clean.min(want);
        e.clean -= overwrite;
        self.clean_total -= overwrite;
        let cap = self.effective_cap();
        let free = cap.saturating_sub(self.clean_total + self.dirty_total);
        if free < want {
            self.evict_clean(want - free);
        }
        let free = cap.saturating_sub(self.clean_total + self.dirty_total);
        let ins = want.min(free);
        self.lru += 1;
        let t = self.lru;
        let e = self.files.entry(file).or_default();
        e.dirty += ins;
        e.tick = t;
        self.dirty_total += ins;
        ins
    }

    /// A writeback of `bytes` of `file` completed: dirty → clean.
    pub fn complete_writeback(&mut self, file: u64, bytes: u64) {
        if let Some(e) = self.files.get_mut(&file) {
            let b = e.dirty.min(bytes);
            e.dirty -= b;
            e.clean += b;
            self.dirty_total -= b;
            self.clean_total += b;
        }
    }

    /// Drop all residency of `file` (unlink). Returns (clean, dirty)
    /// bytes dropped — the caller must cancel matching writeback work.
    pub fn unlink(&mut self, file: u64) -> (u64, u64) {
        match self.files.remove(&file) {
            Some(e) => {
                self.clean_total -= e.clean;
                self.dirty_total -= e.dirty;
                (e.clean, e.dirty)
            }
            None => (0, 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> PageCache {
        PageCache::new(1000, 300)
    }

    #[test]
    fn read_miss_then_hit() {
        let mut pc = cache();
        let (c, m) = pc.read_split(1, 500);
        assert_eq!((c, m), (0, 500));
        assert_eq!(pc.insert_clean(1, 500), 500);
        let (c, m) = pc.read_split(1, 500);
        assert_eq!((c, m), (500, 0));
        assert_eq!(pc.hits, 500);
        assert_eq!(pc.misses, 500);
    }

    #[test]
    fn lru_eviction_order() {
        let mut pc = cache();
        pc.insert_clean(1, 400);
        pc.insert_clean(2, 400);
        pc.read_split(1, 400); // touch 1 -> 2 is LRU
        pc.insert_clean(3, 400); // must evict 2xx bytes from file 2
        assert_eq!(pc.cached(3), 400);
        assert_eq!(pc.cached(1), 400, "recently used survives");
        assert_eq!(pc.cached(2), 200, "LRU partially evicted");
        assert!(pc.resident_total() <= 1000);
    }

    #[test]
    fn write_absorbs_until_dirty_limit() {
        let mut pc = cache();
        let a = pc.absorb_write(1, 250, u64::MAX);
        assert_eq!(a, 250);
        let b = pc.absorb_write(2, 250, u64::MAX);
        assert_eq!(b, 50, "dirty limit 300 binds");
        assert_eq!(pc.dirty_total(), 300);
        assert_eq!(pc.dirty_room(), 0);
    }

    #[test]
    fn per_target_extra_room_binds() {
        let mut pc = cache();
        assert_eq!(pc.absorb_write(1, 200, 120), 120);
    }

    #[test]
    fn writeback_converts_dirty_to_clean() {
        let mut pc = cache();
        pc.absorb_write(1, 300, u64::MAX);
        pc.complete_writeback(1, 200);
        assert_eq!(pc.dirty_of(1), 100);
        assert_eq!(pc.cached(1), 300);
        assert_eq!(pc.dirty_room(), 200);
    }

    #[test]
    fn dirty_pages_never_evicted() {
        let mut pc = cache();
        pc.absorb_write(1, 300, u64::MAX); // dirty 300
        pc.insert_clean(2, 900); // wants 700 free after dirty
        assert_eq!(pc.dirty_of(1), 300);
        assert!(pc.resident_total() <= 1000);
        assert_eq!(pc.cached(2), 700, "clamped by dirty residency");
    }

    #[test]
    fn overwrite_replaces_own_clean_pages() {
        let mut pc = cache();
        pc.insert_clean(1, 200);
        let a = pc.absorb_write(1, 200, u64::MAX);
        assert_eq!(a, 200);
        assert_eq!(pc.cached(1), 200, "no double count");
        assert_eq!(pc.dirty_of(1), 200);
    }

    #[test]
    fn pressure_shrinks_cache() {
        let mut pc = cache();
        pc.insert_clean(1, 800);
        pc.set_pressure(600);
        assert!(pc.resident_total() <= 400);
        assert_eq!(pc.effective_cap(), 400);
    }

    #[test]
    fn unlink_drops_everything() {
        let mut pc = cache();
        pc.insert_clean(1, 100);
        // absorbing 50 dirty bytes overwrites 50 of the clean pages
        pc.absorb_write(1, 50, u64::MAX);
        let (c, d) = pc.unlink(1);
        assert_eq!((c, d), (50, 50));
        assert_eq!(pc.resident_total(), 0);
        assert_eq!(pc.cached(1), 0);
    }

    #[test]
    fn insert_clean_caps_at_capacity() {
        let mut pc = cache();
        assert_eq!(pc.insert_clean(1, 5000), 1000);
        assert_eq!(pc.resident_total(), 1000);
    }
}
