//! Application instruction-VM and the per-node flush-and-evict daemon.
//!
//! A simulated application process executes a sequential program of
//! blocking I/O + compute instructions (exactly Algorithm 1's structure).
//! Placement of every new file is delegated to a [`SimPlacer`] — either
//! the plain-Lustre baseline or Sea's hierarchy policy (module
//! `placement`), so the *same* policy code drives simulation and the
//! real-bytes VFS.
//!
//! After each file is written, the placer returns management actions
//! (flush / evict, per the `.sea_flushlist` / `.sea_evictlist` rules of
//! Table 1) which are queued to the node's single [`FlushDaemon`] —
//! mirroring the paper's one flush-and-evict process per node (§5.1).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::sim::engine::{ProcId, Process, Sim, Step};
use crate::sim::stack::{FileId, Stack, StackState};
use crate::sim::topology::Location;

/// One blocking instruction of an application program.
#[derive(Debug, Clone)]
pub enum Instr {
    /// Read a whole file (wherever it currently lives).
    Read(FileId),
    /// Create/overwrite a file of `size` bytes; destination chosen by the
    /// placer at execution time.
    Write { file: FileId, size: u64 },
    /// Burn CPU for `seconds` (one core).
    Compute { seconds: f64 },
    /// Remove a file.
    Delete(FileId),
}

/// Memory-management action decided by the placer (Table 1 semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MgmtAction {
    /// Copy to Lustre, keep the local copy (mode *Copy*).
    Flush(FileId),
    /// Copy to Lustre, then drop the local copy (mode *Move*).
    FlushEvict(FileId),
    /// Remove without persisting (mode *Remove*).
    Evict(FileId),
}

/// Placement policy driven by the simulator.
pub trait SimPlacer {
    /// Choose where a new `size`-byte file written from `node` goes.
    /// Must never fail: the last-resort destination is Lustre.
    fn place(&mut self, st: &mut StackState, node: usize, file: FileId, size: u64) -> Location;

    /// Called when a file's write has completed; returns management
    /// actions for the node's flush daemon (empty for mode *Keep*).
    fn on_write_complete(&mut self, file: FileId) -> Vec<MgmtAction>;

    /// Called when a local copy was evicted or deleted, so the policy can
    /// credit the freed space.
    fn on_freed(&mut self, loc: Location, size: u64);
}

/// Shared per-node management queues + daemon pids.
pub struct MgmtQueues {
    queues: Vec<RefCell<VecDeque<MgmtAction>>>,
    daemons: RefCell<Vec<ProcId>>,
}

impl MgmtQueues {
    /// Empty queues for `nodes` nodes.
    pub fn new(nodes: usize) -> Rc<MgmtQueues> {
        Rc::new(MgmtQueues {
            queues: (0..nodes).map(|_| RefCell::new(VecDeque::new())).collect(),
            daemons: RefCell::new(Vec::new()),
        })
    }

    /// Enqueue an action for `node`'s daemon and wake it.
    pub fn push(&self, sim: &mut Sim, node: usize, action: MgmtAction) {
        self.queues[node].borrow_mut().push_back(action);
        if let Some(&pid) = self.daemons.borrow().get(node) {
            sim.notify(pid);
        }
    }

    /// All queues empty (quiescence check)?
    pub fn drained(&self) -> bool {
        self.queues.iter().all(|q| q.borrow().is_empty())
    }
}

/// Outcome counters shared by a run's processes.
#[derive(Debug, Default)]
pub struct RunOutcome {
    /// Completion time of each finished app process.
    pub proc_done: Vec<f64>,
    /// Time the last app process finished (the application makespan).
    pub app_done: f64,
    /// Flush actions executed.
    pub flushes: u64,
    /// Evictions executed.
    pub evictions: u64,
    /// Time the last flush-daemon action completed (0 if none ran).
    pub last_mgmt_done: f64,
}

/// An application process executing a program of [`Instr`]s.
pub struct AppProc {
    /// Home node.
    pub node: usize,
    /// Remaining program.
    pub prog: VecDeque<Instr>,
    /// Storage stack handle.
    pub stack: Stack,
    /// Placement policy (shared across all processes of the run).
    pub placer: Rc<RefCell<dyn SimPlacer>>,
    /// Per-node flush daemon queues.
    pub mgmt: Rc<MgmtQueues>,
    /// Shared outcome record.
    pub outcome: Rc<RefCell<RunOutcome>>,
    /// File whose write is in flight (to fire `on_write_complete`).
    pending_write: Option<FileId>,
    /// File whose delete is in flight (to fire `on_freed`).
    pending_delete: Option<(Location, u64)>,
}

impl AppProc {
    /// Create a process for `node` with the given program.
    pub fn new(
        node: usize,
        prog: Vec<Instr>,
        stack: Stack,
        placer: Rc<RefCell<dyn SimPlacer>>,
        mgmt: Rc<MgmtQueues>,
        outcome: Rc<RefCell<RunOutcome>>,
    ) -> AppProc {
        AppProc {
            node,
            prog: prog.into(),
            stack,
            placer,
            mgmt,
            outcome,
            pending_write: None,
            pending_delete: None,
        }
    }
}

impl Process for AppProc {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        // post-completion hooks of the instruction that just finished
        if let Some(file) = self.pending_write.take() {
            let actions = self.placer.borrow_mut().on_write_complete(file);
            for a in actions {
                self.mgmt.push(sim, self.node, a);
            }
        }
        if let Some((loc, size)) = self.pending_delete.take() {
            self.placer.borrow_mut().on_freed(loc, size);
        }
        match self.prog.pop_front() {
            None => {
                let mut out = self.outcome.borrow_mut();
                let t = sim.now();
                out.proc_done.push(t);
                out.app_done = out.app_done.max(t);
                Step::Done
            }
            Some(instr) => {
                match instr {
                    Instr::Read(f) => {
                        self.stack
                            .read(sim, self.node, f, pid)
                            .expect("program read of unknown/remote file");
                    }
                    Instr::Write { file, size } => {
                        let loc = {
                            let stack = self.stack.clone();
                            let mut st = stack.state.borrow_mut();
                            self.placer.borrow_mut().place(&mut st, self.node, file, size)
                        };
                        self.pending_write = Some(file);
                        self.stack
                            .write(sim, self.node, file, size, loc, pid)
                            .expect("program write failed");
                    }
                    Instr::Compute { seconds } => {
                        self.stack.compute(sim, self.node, seconds, pid);
                    }
                    Instr::Delete(f) => {
                        let meta = self.stack.file_meta(f);
                        if let Some(m) = meta {
                            if !matches!(m.loc, Location::Lustre) {
                                self.pending_delete = Some((m.loc, m.size));
                            }
                        }
                        self.stack
                            .delete(sim, self.node, f, pid)
                            .expect("program delete of unknown file");
                    }
                }
                Step::Waiting
            }
        }
    }
}

/// The per-node flush-and-evict daemon (one per node, as in the paper).
pub struct FlushDaemon {
    /// Home node.
    pub node: usize,
    /// Storage stack handle.
    pub stack: Stack,
    /// Shared queues (this daemon serves `queues[node]`).
    pub mgmt: Rc<MgmtQueues>,
    /// Placement policy, for space credits on eviction.
    pub placer: Rc<RefCell<dyn SimPlacer>>,
    /// Shared outcome record.
    pub outcome: Rc<RefCell<RunOutcome>>,
    /// Concurrent transfer budget (spec.flush_parallelism).
    pub parallelism: usize,
    /// Actions in flight, each with its done flag (set by the trampoline
    /// when the underlying op truly finishes — wake-ups from new queue
    /// pushes must not complete them early).
    inflight: Vec<(MgmtAction, Rc<std::cell::Cell<bool>>)>,
}

/// One-shot relay: woken by a storage op's completion, sets the done
/// flag and forwards the wake to the daemon. Spawned with
/// `Sim::spawn_idle`, so its first (and only) resume IS the completion.
struct Trampoline {
    daemon: ProcId,
    done: Rc<std::cell::Cell<bool>>,
}

impl Process for Trampoline {
    fn resume(&mut self, sim: &mut Sim, _pid: ProcId) -> Step {
        self.done.set(true);
        sim.notify(self.daemon);
        Step::Done
    }
}

impl FlushDaemon {
    /// Spawn a daemon for `node` and register its pid in `mgmt`.
    pub fn spawn(
        sim: &mut Sim,
        node: usize,
        stack: Stack,
        mgmt: Rc<MgmtQueues>,
        placer: Rc<RefCell<dyn SimPlacer>>,
        outcome: Rc<RefCell<RunOutcome>>,
    ) -> ProcId {
        let parallelism = stack.state.borrow().spec.flush_parallelism.max(1);
        let pid = sim.spawn(Box::new(FlushDaemon {
            node,
            stack,
            mgmt: mgmt.clone(),
            placer,
            outcome,
            parallelism,
            inflight: Vec::new(),
        }));
        let mut daemons = mgmt.daemons.borrow_mut();
        if daemons.len() <= node {
            daemons.resize(node + 1, pid);
        }
        daemons[node] = pid;
        pid
    }

    fn finish_action(&mut self, action: MgmtAction) {
        let mut out = self.outcome.borrow_mut();
        match action {
            MgmtAction::Flush(_) => out.flushes += 1,
            MgmtAction::FlushEvict(f) => {
                out.flushes += 1;
                out.evictions += 1;
                drop(out);
                // space credit for the evicted local copy
                if let Some(m) = self.stack.file_meta(f) {
                    // after FlushEvict the registry primary is Lustre;
                    // the placer was already credited by evict_local's
                    // caller — here *we* are that caller, so credit now.
                    let _ = m;
                }
            }
            MgmtAction::Evict(_) => out.evictions += 1,
        }
    }
}

impl Process for FlushDaemon {
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
        // complete every in-flight action whose relay has fired; wakes
        // from new queue pushes while busy complete nothing
        let mut finished = Vec::new();
        self.inflight.retain(|(action, done)| {
            if done.get() {
                finished.push(*action);
                false
            } else {
                true
            }
        });
        if !finished.is_empty() {
            for action in finished {
                self.finish_action(action);
            }
            let mut out = self.outcome.borrow_mut();
            out.last_mgmt_done = out.last_mgmt_done.max(sim.now());
        }
        // start new actions up to the parallelism budget
        while self.inflight.len() < self.parallelism {
            let next = self.mgmt.queues[self.node].borrow_mut().pop_front();
            let Some(action) = next else { break };
            let done = Rc::new(std::cell::Cell::new(false));
            let relay = sim.spawn_idle(Box::new(Trampoline {
                daemon: pid,
                done: done.clone(),
            }));
            match action {
                MgmtAction::Flush(f) => {
                    if self.stack.flush(sim, self.node, f, false, relay).is_err() {
                        sim.notify(relay); // skip broken entries
                    }
                }
                MgmtAction::FlushEvict(f) => {
                    // capture size/loc for the space credit before the
                    // move invalidates them
                    let before = self.stack.file_meta(f);
                    if self.stack.flush(sim, self.node, f, true, relay).is_err() {
                        sim.notify(relay);
                    } else if let Some(m) = before {
                        if !matches!(m.loc, Location::Lustre) {
                            self.placer.borrow_mut().on_freed(m.loc, m.size);
                        }
                    }
                }
                MgmtAction::Evict(f) => {
                    let before = self.stack.file_meta(f);
                    if self.stack.delete(sim, self.node, f, relay).is_err() {
                        sim.notify(relay);
                    } else if let Some(m) = before {
                        if !matches!(m.loc, Location::Lustre) {
                            self.placer.borrow_mut().on_freed(m.loc, m.size);
                        }
                    }
                }
            }
            self.inflight.push((action, done));
        }
        Step::Waiting
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::ClusterSpec;
    use crate::util::{GIB, MIB};

    /// Trivial placer: everything to tmpfs, flush+evict nothing.
    struct TmpfsPlacer;
    impl SimPlacer for TmpfsPlacer {
        fn place(&mut self, _st: &mut StackState, node: usize, _f: FileId, _s: u64) -> Location {
            Location::Tmpfs { node }
        }
        fn on_write_complete(&mut self, _file: FileId) -> Vec<MgmtAction> {
            vec![]
        }
        fn on_freed(&mut self, _loc: Location, _size: u64) {}
    }

    /// Placer that flushes+evicts every file (copy-all + evict).
    struct MoveAllPlacer;
    impl SimPlacer for MoveAllPlacer {
        fn place(&mut self, _st: &mut StackState, node: usize, _f: FileId, _s: u64) -> Location {
            Location::Tmpfs { node }
        }
        fn on_write_complete(&mut self, file: FileId) -> Vec<MgmtAction> {
            vec![MgmtAction::FlushEvict(file)]
        }
        fn on_freed(&mut self, _loc: Location, _size: u64) {}
    }

    fn small_spec() -> ClusterSpec {
        let mut s = ClusterSpec {
            nodes: 1,
            procs_per_node: 1,
            cores_per_node: 4,
            mem_bytes: 10 * GIB,
            tmpfs_bytes: 4 * GIB,
            mem_read_bw: 1000.0 * MIB as f64,
            mem_write_bw: 500.0 * MIB as f64,
            disks_per_node: 1,
            disk_bytes: 100 * GIB,
            disk_read_bw: 100.0 * MIB as f64,
            disk_write_bw: 50.0 * MIB as f64,
            nic_bw: 1000.0 * MIB as f64,
            dirty_ratio: 0.2,
            cacheable_ratio: 0.8,
            ..ClusterSpec::default()
        };
        s.lustre.ost_write_bw = 100.0 * MIB as f64;
        s.lustre.ost_read_bw = 200.0 * MIB as f64;
        s.lustre.server_nic_bw = 1000.0 * MIB as f64;
        s.lustre.mds_ops_per_mib_written = 0.0;
        s
    }

    fn run_app(
        spec: &ClusterSpec,
        placer: Rc<RefCell<dyn SimPlacer>>,
        progs: Vec<Vec<Instr>>,
        inputs: &[(FileId, u64)],
    ) -> (f64, Rc<RefCell<RunOutcome>>) {
        let mut sim = Sim::new();
        let stack = Stack::new(&mut sim, spec);
        for &(f, s) in inputs {
            stack.register_file(f, s, Location::Lustre);
        }
        let mgmt = MgmtQueues::new(spec.nodes);
        let outcome = Rc::new(RefCell::new(RunOutcome::default()));
        for node in 0..spec.nodes {
            FlushDaemon::spawn(
                &mut sim,
                node,
                stack.clone(),
                mgmt.clone(),
                placer.clone(),
                outcome.clone(),
            );
        }
        for (i, prog) in progs.into_iter().enumerate() {
            let node = i % spec.nodes;
            sim.spawn(Box::new(AppProc::new(
                node,
                prog,
                stack.clone(),
                placer.clone(),
                mgmt.clone(),
                outcome.clone(),
            )));
        }
        let t = sim.run(1e12).unwrap();
        assert!(mgmt.drained(), "flush queues drained at quiescence");
        (t, outcome)
    }

    #[test]
    fn single_proc_read_compute_write() {
        let spec = small_spec();
        let placer = Rc::new(RefCell::new(TmpfsPlacer));
        let prog = vec![
            Instr::Read(1),
            Instr::Compute { seconds: 1.0 },
            Instr::Write { file: 100, size: 200 * MIB },
        ];
        let (t, out) = run_app(&spec, placer, vec![prog], &[(1, 200 * MIB)]);
        // read 200 MiB @ 200 MiB/s (+1ms mds) + compute 1s + write @500
        let expect = 1.0 + 0.001 + 1.0 + 0.4;
        assert!((t - expect).abs() < 5e-3, "t = {t}, expect ≈ {expect}");
        assert_eq!(out.borrow().proc_done.len(), 1);
    }

    #[test]
    fn flush_evict_moves_file_to_lustre() {
        let spec = small_spec();
        let placer = Rc::new(RefCell::new(MoveAllPlacer));
        let prog = vec![Instr::Write { file: 100, size: 100 * MIB }];
        let mut sim_check = None;
        let (t, out) = {
            let mut sim = Sim::new();
            let stack = Stack::new(&mut sim, &spec);
            let mgmt = MgmtQueues::new(spec.nodes);
            let outcome = Rc::new(RefCell::new(RunOutcome::default()));
            FlushDaemon::spawn(
                &mut sim, 0, stack.clone(), mgmt.clone(),
                placer.clone(), outcome.clone(),
            );
            sim.spawn(Box::new(AppProc::new(
                0, prog, stack.clone(), placer, mgmt, outcome.clone(),
            )));
            let t = sim.run(1e12).unwrap();
            sim_check = Some(stack.file_meta(100).unwrap());
            (t, outcome)
        };
        let meta = sim_check.unwrap();
        assert!(matches!(meta.loc, Location::Lustre), "moved to lustre: {meta:?}");
        assert!(!meta.lustre_replica);
        assert_eq!(out.borrow().flushes, 1);
        assert_eq!(out.borrow().evictions, 1);
        // app write (0.2s) + flush read (0.1s) + lustre write ≥ 1s
        assert!(t > 1.0, "t = {t}");
    }

    #[test]
    fn app_done_before_flush_completes() {
        // the app's makespan excludes the asynchronous flush tail
        let spec = small_spec();
        let placer = Rc::new(RefCell::new(MoveAllPlacer));
        let prog = vec![Instr::Write { file: 100, size: 500 * MIB }];
        let (t_quiescent, out) = run_app(&spec, placer, vec![prog], &[]);
        let app_done = out.borrow().app_done;
        assert!(app_done < t_quiescent, "flush runs past app exit");
    }

    #[test]
    fn parallel_procs_contend_on_memory_bus() {
        let spec = small_spec();
        let placer = Rc::new(RefCell::new(TmpfsPlacer));
        let one = vec![Instr::Write { file: 100, size: 500 * MIB }];
        let two = vec![Instr::Write { file: 101, size: 500 * MIB }];
        let (t, _) = run_app(&spec, placer, vec![one, two], &[]);
        // two 500 MiB writes share the 500 MiB/s mem_w lane -> 2s
        assert!((t - 2.0).abs() < 1e-6, "t = {t}");
    }

    #[test]
    fn delete_fires_on_freed() {
        struct CountingPlacer {
            freed: u64,
        }
        impl SimPlacer for CountingPlacer {
            fn place(&mut self, _st: &mut StackState, node: usize, _f: FileId, _s: u64) -> Location {
                Location::Tmpfs { node }
            }
            fn on_write_complete(&mut self, _f: FileId) -> Vec<MgmtAction> {
                vec![]
            }
            fn on_freed(&mut self, _loc: Location, size: u64) {
                self.freed += size;
            }
        }
        let spec = small_spec();
        let placer = Rc::new(RefCell::new(CountingPlacer { freed: 0 }));
        let prog = vec![
            Instr::Write { file: 100, size: 100 * MIB },
            Instr::Delete(100),
        ];
        let placer2 = placer.clone();
        let (_t, _) = run_app(&spec, placer, vec![prog], &[]);
        assert_eq!(placer2.borrow().freed, 100 * MIB);
    }
}
