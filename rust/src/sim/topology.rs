//! Maps a [`ClusterSpec`] onto engine resources and names storage
//! locations.
//!
//! Resources created per compute node: memory-bus read/write lanes, a CPU
//! pool (capacity = cores, compute flows capped at 1), NIC in/out lanes,
//! and per-disk read/write lanes. Per Lustre OSS: NIC in/out. Per OST:
//! read/write lanes. One MDS processor-sharing service for the whole file
//! system. Paths for a Lustre transfer traverse client NIC → server NIC →
//! OST, reproducing the `min(cN, sN, d·min(d,cp))` structure of the
//! paper's Eqs. (2)–(3).

use crate::sim::engine::{ResourceId, Sim};
use crate::sim::spec::ClusterSpec;

/// Where bytes live, from a single node's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Location {
    /// Node-local tmpfs (RAM-backed).
    Tmpfs { node: usize },
    /// Node-local disk `disk` on `node`.
    Disk { node: usize, disk: usize },
    /// The shared parallel file system; files are assigned an OST.
    Lustre,
}

impl Location {
    /// Human-readable tier name (matches Table 2 rows).
    pub fn tier_name(&self) -> &'static str {
        match self {
            Location::Tmpfs { .. } => "tmpfs",
            Location::Disk { .. } => "local disk",
            Location::Lustre => "lustre",
        }
    }

    /// Is this location on the given node (Lustre is on no node)?
    pub fn on_node(&self, n: usize) -> bool {
        match *self {
            Location::Tmpfs { node } => node == n,
            Location::Disk { node, .. } => node == n,
            Location::Lustre => false,
        }
    }
}

/// Per-node resource handles.
#[derive(Debug, Clone)]
pub struct NodeRes {
    /// Memory bus, read direction (page-cache & tmpfs reads).
    pub mem_r: ResourceId,
    /// Memory bus, write direction.
    pub mem_w: ResourceId,
    /// CPU pool (capacity = cores; compute flows capped at 1.0).
    pub cpu: ResourceId,
    /// NIC, node → fabric.
    pub nic_out: ResourceId,
    /// NIC, fabric → node.
    pub nic_in: ResourceId,
    /// Per-disk read lanes.
    pub disk_r: Vec<ResourceId>,
    /// Per-disk write lanes.
    pub disk_w: Vec<ResourceId>,
}

/// Per-OSS resource handles.
#[derive(Debug, Clone)]
pub struct OssRes {
    /// Server NIC, fabric → server (writes land here).
    pub nic_in: ResourceId,
    /// Server NIC, server → fabric (reads come from here).
    pub nic_out: ResourceId,
    /// Read lane per OST hosted by this server.
    pub ost_r: Vec<ResourceId>,
    /// Write lane per OST hosted by this server.
    pub ost_w: Vec<ResourceId>,
}

/// All resource handles for a built cluster.
#[derive(Debug, Clone)]
pub struct Topology {
    /// The spec this topology was built from.
    pub spec: ClusterSpec,
    /// Compute-node resources, indexed by node id.
    pub nodes: Vec<NodeRes>,
    /// OSS resources, indexed by server id.
    pub oss: Vec<OssRes>,
    /// MDS processor-sharing service (units = metadata ops).
    pub mds: ResourceId,
}

impl Topology {
    /// Instantiate all resources for `spec` inside `sim`.
    pub fn build(sim: &mut Sim, spec: &ClusterSpec) -> Topology {
        let mut nodes = Vec::with_capacity(spec.nodes);
        for n in 0..spec.nodes {
            let mem_r = sim.add_resource(format!("n{n}.mem_r"), spec.mem_read_bw);
            let mem_w = sim.add_resource(format!("n{n}.mem_w"), spec.mem_write_bw);
            let cpu = sim.add_resource(format!("n{n}.cpu"), spec.cores_per_node as f64);
            let nic_out = sim.add_resource(format!("n{n}.nic_out"), spec.nic_bw);
            let nic_in = sim.add_resource(format!("n{n}.nic_in"), spec.nic_bw);
            let mut disk_r = Vec::with_capacity(spec.disks_per_node);
            let mut disk_w = Vec::with_capacity(spec.disks_per_node);
            for d in 0..spec.disks_per_node {
                disk_r.push(sim.add_resource(format!("n{n}.d{d}.r"), spec.disk_read_bw));
                disk_w.push(sim.add_resource(format!("n{n}.d{d}.w"), spec.disk_write_bw));
            }
            nodes.push(NodeRes { mem_r, mem_w, cpu, nic_out, nic_in, disk_r, disk_w });
        }
        let mut oss = Vec::with_capacity(spec.lustre.oss_count);
        for s in 0..spec.lustre.oss_count {
            let nic_in = sim.add_resource(format!("oss{s}.nic_in"), spec.lustre.server_nic_bw);
            let nic_out =
                sim.add_resource(format!("oss{s}.nic_out"), spec.lustre.server_nic_bw);
            let mut ost_r = Vec::with_capacity(spec.lustre.osts_per_oss);
            let mut ost_w = Vec::with_capacity(spec.lustre.osts_per_oss);
            for t in 0..spec.lustre.osts_per_oss {
                ost_r.push(sim.add_resource(format!("oss{s}.ost{t}.r"), spec.lustre.ost_read_bw));
                ost_w.push(
                    sim.add_resource(format!("oss{s}.ost{t}.w"), spec.lustre.ost_write_bw),
                );
            }
            oss.push(OssRes { nic_in, nic_out, ost_r, ost_w });
        }
        let mds = sim.add_resource("mds", spec.lustre.mds_ops_per_sec);
        Topology { spec: spec.clone(), nodes, oss, mds }
    }

    /// Map a global OST index to (server, local OST index).
    pub fn ost_of(&self, global_ost: usize) -> (usize, usize) {
        let per = self.spec.lustre.osts_per_oss;
        (global_ost / per % self.spec.lustre.oss_count, global_ost % per)
    }

    /// Resource path for reading `bytes` of a file on OST `ost` from
    /// `node`: OST read lane → server NIC out → client NIC in.
    pub fn lustre_read_path(&self, node: usize, ost: usize) -> Vec<ResourceId> {
        let (s, t) = self.ost_of(ost);
        vec![self.oss[s].ost_r[t], self.oss[s].nic_out, self.nodes[node].nic_in]
    }

    /// Resource path for writing to OST `ost` from `node`.
    pub fn lustre_write_path(&self, node: usize, ost: usize) -> Vec<ResourceId> {
        let (s, t) = self.ost_of(ost);
        vec![self.nodes[node].nic_out, self.oss[s].nic_in, self.oss[s].ost_w[t]]
    }

    /// Resource path for a local device read on `node`.
    pub fn local_read_path(&self, loc: Location) -> Vec<ResourceId> {
        match loc {
            Location::Tmpfs { node } => vec![self.nodes[node].mem_r],
            Location::Disk { node, disk } => vec![self.nodes[node].disk_r[disk]],
            Location::Lustre => unreachable!("lustre path needs an OST"),
        }
    }

    /// Resource path for a local device write on `node`.
    pub fn local_write_path(&self, loc: Location) -> Vec<ResourceId> {
        match loc {
            Location::Tmpfs { node } => vec![self.nodes[node].mem_w],
            Location::Disk { node, disk } => vec![self.nodes[node].disk_w[disk]],
            Location::Lustre => unreachable!("lustre path needs an OST"),
        }
    }

    /// Page-cache read path (always the node's memory bus).
    pub fn cache_read_path(&self, node: usize) -> Vec<ResourceId> {
        vec![self.nodes[node].mem_r]
    }

    /// Page-cache write path.
    pub fn cache_write_path(&self, node: usize) -> Vec<ResourceId> {
        vec![self.nodes[node].mem_w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Sim;

    #[test]
    fn builds_paper_topology() {
        let mut sim = Sim::new();
        let spec = ClusterSpec::paper_default();
        let topo = Topology::build(&mut sim, &spec);
        assert_eq!(topo.nodes.len(), 5);
        assert_eq!(topo.nodes[0].disk_r.len(), 6);
        assert_eq!(topo.oss.len(), 4);
        assert_eq!(topo.oss[0].ost_r.len(), 11);
    }

    #[test]
    fn ost_mapping_covers_all_servers() {
        let mut sim = Sim::new();
        let spec = ClusterSpec::paper_default();
        let topo = Topology::build(&mut sim, &spec);
        let mut seen = std::collections::HashSet::new();
        for g in 0..44 {
            let (s, t) = topo.ost_of(g);
            assert!(s < 4 && t < 11);
            seen.insert((s, t));
        }
        assert_eq!(seen.len(), 44, "44 distinct OSTs");
    }

    #[test]
    fn paths_have_expected_hops() {
        let mut sim = Sim::new();
        let spec = ClusterSpec::paper_default();
        let topo = Topology::build(&mut sim, &spec);
        assert_eq!(topo.lustre_read_path(0, 3).len(), 3);
        assert_eq!(topo.lustre_write_path(1, 7).len(), 3);
        assert_eq!(topo.local_read_path(Location::Tmpfs { node: 2 }).len(), 1);
        assert_eq!(
            topo.local_write_path(Location::Disk { node: 0, disk: 5 }).len(),
            1
        );
    }

    #[test]
    fn location_helpers() {
        assert_eq!(Location::Lustre.tier_name(), "lustre");
        assert!(Location::Tmpfs { node: 1 }.on_node(1));
        assert!(!Location::Disk { node: 1, disk: 0 }.on_node(2));
        assert!(!Location::Lustre.on_node(0));
    }
}
