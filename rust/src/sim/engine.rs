//! Fluid-flow discrete-event engine with max-min fair sharing.
//!
//! Everything that consumes a rated capacity is a **flow**: a data
//! transfer over a NIC+disk path, a metadata op through the MDS
//! (processor-sharing queue), or a compute burst through a node's CPU
//! (per-flow rate cap of one core). Rates are reallocated with the
//! progressive-filling (max-min fair) algorithm whenever the flow set
//! changes; between changes every flow progresses linearly, so the next
//! interesting instant is the earliest completion — a classic fluid DES.
//!
//! Simulated **processes** are cooperative state machines: a process is
//! resumed, issues at most one blocking request (flow / sleep), and
//! returns [`Step::Waiting`]. Completion wakes it again. Daemons (page
//! cache writeback) additionally get woken by condition notifications.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::error::{Error, Result};

/// Simulated time in seconds.
pub type Time = f64;

/// Tolerance for "flow is finished" in float bytes.
const EPS_BYTES: f64 = 1e-6;
/// Tolerance when comparing candidate bottleneck rates.
const EPS_RATE: f64 = 1e-12;

/// Identifies a rated resource (NIC, disk, memory bus, CPU, MDS).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(pub(crate) u32);

/// Identifies a live flow (generation-checked).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowId {
    idx: u32,
    gen: u32,
}

/// Identifies a simulated process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProcId(pub(crate) u32);

/// What a resumed process tells the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Blocked on the request it just issued (or idle awaiting notify).
    Waiting,
    /// Finished; will never be resumed again.
    Done,
}

/// A cooperative simulated process.
pub trait Process {
    /// Resume after the awaited event (or a notification). The process
    /// may issue new requests through [`Sim`] before returning.
    fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step;
}

#[derive(Debug)]
struct Resource {
    capacity: f64,
    #[allow(dead_code)]
    name: String,
    /// Cumulative busy integral (bytes through this resource), for
    /// utilization reporting.
    work_done: f64,
}

struct Flow {
    path: Vec<ResourceId>,
    remaining: f64,
    rate: f64,
    cap: f64,
    waker: Option<ProcId>,
    gen: u32,
    alive: bool,
}

/// Totally-ordered f64 key for the event heap (times are never NaN).
#[derive(PartialEq, PartialOrd)]
struct TimeKey(f64);
impl Eq for TimeKey {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).expect("NaN time")
    }
}

enum EventKind {
    /// Re-examine flow completions; valid only for the matching epoch.
    FlowCheck { epoch: u64 },
    /// Wake a sleeping process.
    Timer { pid: ProcId },
}

/// The simulation engine.
pub struct Sim {
    now: Time,
    /// Time up to which flow progress has been integrated.
    last_settle: Time,
    seq: u64,
    epoch: u64,
    events: BinaryHeap<Reverse<(TimeKey, u64, EventWrap)>>,
    resources: Vec<Resource>,
    flows: Vec<Flow>,
    free_flows: Vec<u32>,
    active: Vec<u32>,
    processes: Vec<Option<Box<dyn Process>>>,
    runnable: Vec<ProcId>,
    /// Statistics: completed flow count.
    pub flows_completed: u64,
    /// Statistics: rate recomputations.
    pub recomputes: u64,
    /// scratch buffers for the progressive-filling pass (perf)
    scratch_rem: Vec<f64>,
    scratch_cnt: Vec<u32>,
}

struct EventWrap(EventKind);
// Heap ordering only uses (TimeKey, seq); EventWrap comparisons are moot.
impl PartialEq for EventWrap {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl Eq for EventWrap {}
impl PartialOrd for EventWrap {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EventWrap {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    /// New empty simulation at t = 0.
    pub fn new() -> Sim {
        Sim {
            now: 0.0,
            last_settle: 0.0,
            seq: 0,
            epoch: 0,
            events: BinaryHeap::new(),
            resources: Vec::new(),
            flows: Vec::new(),
            free_flows: Vec::new(),
            active: Vec::new(),
            processes: Vec::new(),
            runnable: Vec::new(),
            flows_completed: 0,
            recomputes: 0,
            scratch_rem: Vec::new(),
            scratch_cnt: Vec::new(),
        }
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Register a rated resource (capacity in units/second).
    pub fn add_resource(&mut self, name: impl Into<String>, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        let id = ResourceId(self.resources.len() as u32);
        self.resources.push(Resource { capacity, name: name.into(), work_done: 0.0 });
        id
    }

    /// Total units moved through a resource so far (utilization numerator).
    pub fn resource_work(&self, r: ResourceId) -> f64 {
        self.resources[r.0 as usize].work_done
    }

    /// Resource capacity.
    pub fn resource_capacity(&self, r: ResourceId) -> f64 {
        self.resources[r.0 as usize].capacity
    }

    /// Register a process; it is made runnable immediately.
    pub fn spawn(&mut self, p: Box<dyn Process>) -> ProcId {
        let pid = self.spawn_idle(p);
        self.runnable.push(pid);
        pid
    }

    /// Register a process WITHOUT making it runnable: it only runs when
    /// explicitly notified (completion relays, condition waiters).
    pub fn spawn_idle(&mut self, p: Box<dyn Process>) -> ProcId {
        let pid = ProcId(self.processes.len() as u32);
        self.processes.push(Some(p));
        pid
    }

    /// Make a process runnable now (condition notify). Idempotent per tick.
    pub fn notify(&mut self, pid: ProcId) {
        if !self.runnable.contains(&pid) {
            self.runnable.push(pid);
        }
    }

    /// Start a flow of `units` over `path`, optionally rate-capped, waking
    /// `waker` on completion. Instantaneous zero-unit flows complete at
    /// once (waker still queued).
    pub fn start_flow(
        &mut self,
        path: Vec<ResourceId>,
        units: f64,
        cap: f64,
        waker: Option<ProcId>,
    ) -> FlowId {
        assert!(units >= 0.0 && cap > 0.0);
        self.settle();
        if units <= EPS_BYTES {
            if let Some(pid) = waker {
                self.notify(pid);
            }
            // a degenerate, already-dead flow id
            return FlowId { idx: u32::MAX, gen: 0 };
        }
        let idx = match self.free_flows.pop() {
            Some(i) => i,
            None => {
                self.flows.push(Flow {
                    path: Vec::new(),
                    remaining: 0.0,
                    rate: 0.0,
                    cap: f64::INFINITY,
                    waker: None,
                    gen: 0,
                    alive: false,
                });
                (self.flows.len() - 1) as u32
            }
        };
        let f = &mut self.flows[idx as usize];
        f.path = path;
        f.remaining = units;
        f.rate = 0.0;
        f.cap = cap;
        f.waker = waker;
        f.gen = f.gen.wrapping_add(1);
        f.alive = true;
        let gen = f.gen;
        self.active.push(idx);
        self.reallocate();
        FlowId { idx, gen }
    }

    /// Is a flow still in progress?
    pub fn flow_alive(&self, id: FlowId) -> bool {
        id.idx != u32::MAX
            && (id.idx as usize) < self.flows.len()
            && self.flows[id.idx as usize].alive
            && self.flows[id.idx as usize].gen == id.gen
    }

    /// Sleep: wake `pid` after `dt` seconds.
    pub fn sleep(&mut self, pid: ProcId, dt: f64) {
        assert!(dt >= 0.0);
        let at = self.now + dt;
        self.push_event(at, EventKind::Timer { pid });
    }

    fn push_event(&mut self, at: Time, kind: EventKind) {
        self.seq += 1;
        self.events.push(Reverse((TimeKey(at), self.seq, EventWrap(kind))));
    }

    /// Advance all active flows' progress to `self.now`.
    fn settle(&mut self) {
        // `now` only moves inside run(); callers set it before settle.
        // progress = rate * elapsed is tracked lazily: we store remaining
        // relative to last settle time via `last_settle`.
        let dt = self.now - self.last_settle;
        if dt > 0.0 {
            for &idx in &self.active {
                let f = &mut self.flows[idx as usize];
                let moved = f.rate * dt;
                f.remaining -= moved;
                for &r in &f.path {
                    self.resources[r.0 as usize].work_done += moved;
                }
            }
        }
        self.last_settle = self.now;
    }

    /// Max-min fair (progressive filling) reallocation with per-flow caps.
    fn reallocate(&mut self) {
        self.recomputes += 1;
        let nres = self.resources.len();
        self.scratch_rem.clear();
        self.scratch_rem.extend(self.resources.iter().map(|r| r.capacity));
        self.scratch_cnt.clear();
        self.scratch_cnt.resize(nres, 0);

        // unfrozen = active flows not yet assigned a final rate
        let mut unfrozen: Vec<u32> = self.active.clone();
        for &idx in &unfrozen {
            for &r in &self.flows[idx as usize].path {
                self.scratch_cnt[r.0 as usize] += 1;
            }
        }
        while !unfrozen.is_empty() {
            // candidate bottleneck rate: min over resources of fair share,
            // and min over flows of their cap
            let mut rate = f64::INFINITY;
            for r in 0..nres {
                if self.scratch_cnt[r] > 0 {
                    rate = rate.min(self.scratch_rem[r] / self.scratch_cnt[r] as f64);
                }
            }
            let min_cap = unfrozen
                .iter()
                .map(|&i| self.flows[i as usize].cap)
                .fold(f64::INFINITY, f64::min);
            let capped_round = min_cap < rate - EPS_RATE;
            let round_rate = rate.min(min_cap).max(0.0);

            if capped_round {
                // freeze only flows at the cap
                let mut next = Vec::with_capacity(unfrozen.len());
                for &i in &unfrozen {
                    let f = &self.flows[i as usize];
                    if f.cap <= round_rate + EPS_RATE {
                        self.flows[i as usize].rate = round_rate;
                        for &r in &self.flows[i as usize].path.clone() {
                            self.scratch_rem[r.0 as usize] =
                                (self.scratch_rem[r.0 as usize] - round_rate).max(0.0);
                            self.scratch_cnt[r.0 as usize] -= 1;
                        }
                    } else {
                        next.push(i);
                    }
                }
                unfrozen = next;
            } else {
                // freeze all flows through the bottleneck resource(s)
                let mut bottlenecks = Vec::new();
                for r in 0..nres {
                    if self.scratch_cnt[r] > 0
                        && self.scratch_rem[r] / self.scratch_cnt[r] as f64
                            <= round_rate + EPS_RATE
                    {
                        bottlenecks.push(r);
                    }
                }
                let mut next = Vec::with_capacity(unfrozen.len());
                for &i in &unfrozen {
                    let through = self.flows[i as usize]
                        .path
                        .iter()
                        .any(|r| bottlenecks.contains(&(r.0 as usize)));
                    if through {
                        self.flows[i as usize].rate = round_rate;
                        for &r in &self.flows[i as usize].path.clone() {
                            self.scratch_rem[r.0 as usize] =
                                (self.scratch_rem[r.0 as usize] - round_rate).max(0.0);
                            self.scratch_cnt[r.0 as usize] -= 1;
                        }
                    } else {
                        next.push(i);
                    }
                }
                // safety: if nothing froze (degenerate), freeze everything
                if next.len() == unfrozen.len() {
                    for &i in &next {
                        self.flows[i as usize].rate = round_rate;
                    }
                    next.clear();
                }
                unfrozen = next;
            }
        }
        // schedule the next completion check
        self.epoch += 1;
        let mut t_next = f64::INFINITY;
        for &idx in &self.active {
            let f = &self.flows[idx as usize];
            if f.rate > 0.0 {
                t_next = t_next.min(self.now + f.remaining / f.rate);
            }
        }
        if t_next.is_finite() {
            let epoch = self.epoch;
            self.push_event(t_next.max(self.now), EventKind::FlowCheck { epoch });
        }
    }

    fn complete_finished_flows(&mut self) {
        let mut finished = Vec::new();
        let flows = &self.flows;
        self.active.retain(|&idx| {
            let f = &flows[idx as usize];
            // Completion threshold is rate-relative: after settling, a
            // flow can hold an f64 ulp residue proportional to its size
            // (~100 bytes on a 600 MiB transfer). Anything representing
            // less than a microsecond of remaining work is done —
            // otherwise each residue respawns an O(flows·resources)
            // reallocation microevent and large runs crawl.
            if f.remaining <= EPS_BYTES.max(f.rate * 1e-6) {
                finished.push(idx);
                false
            } else {
                true
            }
        });
        for idx in finished {
            let f = &mut self.flows[idx as usize];
            f.alive = false;
            f.remaining = 0.0;
            f.rate = 0.0;
            let waker = f.waker.take();
            self.free_flows.push(idx);
            self.flows_completed += 1;
            if let Some(pid) = waker {
                self.notify(pid);
            }
        }
    }

    fn run_runnable(&mut self) {
        while let Some(pid) = self.runnable.pop() {
            let slot = pid.0 as usize;
            let mut proc = match self.processes[slot].take() {
                Some(p) => p,
                None => continue, // already done
            };
            let step = proc.resume(self, pid);
            match step {
                Step::Waiting => self.processes[slot] = Some(proc),
                Step::Done => { /* drop */ }
            }
        }
    }

    /// Run until no events remain or `max_time` is exceeded.
    /// Returns the final simulated time.
    pub fn run(&mut self, max_time: Time) -> Result<Time> {
        self.run_runnable();
        while let Some(Reverse((TimeKey(t), _, EventWrap(kind)))) = self.events.pop() {
            if t > max_time {
                return Err(Error::Sim(format!(
                    "simulation exceeded max_time {max_time}s (at {t:.3}s, {} active flows)",
                    self.active.len()
                )));
            }
            debug_assert!(t >= self.now - 1e-9, "time went backwards");
            self.now = t.max(self.now);
            match kind {
                EventKind::FlowCheck { epoch } => {
                    if epoch != self.epoch {
                        continue; // stale
                    }
                    self.settle();
                    self.complete_finished_flows();
                    self.run_runnable();
                    // runnable processes may have started flows (which
                    // reallocate) — only reallocate if they didn't
                    self.settle();
                    self.reallocate();
                }
                EventKind::Timer { pid } => {
                    self.settle();
                    self.notify(pid);
                    self.run_runnable();
                    self.settle();
                    self.reallocate();
                }
            }
        }
        if !self.active.is_empty() {
            return Err(Error::Sim(format!(
                "event queue drained with {} flows still active (starved at rate 0?)",
                self.active.len()
            )));
        }
        Ok(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A process that runs one flow of `units` over `path` then finishes,
    /// recording the completion time.
    struct OneFlow {
        path: Vec<ResourceId>,
        units: f64,
        cap: f64,
        started: bool,
        done_at: std::rc::Rc<std::cell::Cell<f64>>,
    }
    impl Process for OneFlow {
        fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
            if !self.started {
                self.started = true;
                sim.start_flow(self.path.clone(), self.units, self.cap, Some(pid));
                Step::Waiting
            } else {
                self.done_at.set(sim.now());
                Step::Done
            }
        }
    }

    fn one_flow(
        sim: &mut Sim,
        path: Vec<ResourceId>,
        units: f64,
        cap: f64,
    ) -> std::rc::Rc<std::cell::Cell<f64>> {
        let cell = std::rc::Rc::new(std::cell::Cell::new(-1.0));
        sim.spawn(Box::new(OneFlow {
            path,
            units,
            cap,
            started: false,
            done_at: cell.clone(),
        }));
        cell
    }

    #[test]
    fn single_flow_takes_bytes_over_bandwidth() {
        let mut sim = Sim::new();
        let r = sim.add_resource("disk", 100.0);
        let t = one_flow(&mut sim, vec![r], 1000.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert!((t.get() - 10.0).abs() < 1e-6, "got {}", t.get());
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let r = sim.add_resource("disk", 100.0);
        let a = one_flow(&mut sim, vec![r], 1000.0, f64::INFINITY);
        let b = one_flow(&mut sim, vec![r], 1000.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        // both at 50 -> both complete at t = 20
        assert!((a.get() - 20.0).abs() < 1e-6, "a at {}", a.get());
        assert!((b.get() - 20.0).abs() < 1e-6, "b at {}", b.get());
    }

    #[test]
    fn shorter_flow_frees_bandwidth() {
        let mut sim = Sim::new();
        let r = sim.add_resource("disk", 100.0);
        let a = one_flow(&mut sim, vec![r], 500.0, f64::INFINITY);
        let b = one_flow(&mut sim, vec![r], 1500.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        // a: 500 at 50/s -> t=10. b: 500 by t=10, then 1000 at 100/s -> t=20
        assert!((a.get() - 10.0).abs() < 1e-6, "a at {}", a.get());
        assert!((b.get() - 20.0).abs() < 1e-6, "b at {}", b.get());
    }

    #[test]
    fn min_over_path_resources() {
        let mut sim = Sim::new();
        let fast = sim.add_resource("nic", 1000.0);
        let slow = sim.add_resource("disk", 10.0);
        let t = one_flow(&mut sim, vec![fast, slow], 100.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert!((t.get() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn per_flow_cap_binds() {
        let mut sim = Sim::new();
        let r = sim.add_resource("mds", 1000.0);
        // one op, capped at 10/s: takes 0.1 units/(10/s) ... 1 unit -> 0.1s
        let t = one_flow(&mut sim, vec![r], 1.0, 10.0);
        sim.run(1e9).unwrap();
        assert!((t.get() - 0.1).abs() < 1e-9, "got {}", t.get());
    }

    #[test]
    fn capped_flows_leave_headroom_for_others() {
        let mut sim = Sim::new();
        let r = sim.add_resource("link", 100.0);
        // capped flow uses 10, uncapped gets the remaining 90
        let a = one_flow(&mut sim, vec![r], 100.0, 10.0);
        let b = one_flow(&mut sim, vec![r], 900.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert!((a.get() - 10.0).abs() < 1e-6, "a at {}", a.get());
        assert!((b.get() - 10.0).abs() < 1e-6, "b at {}", b.get());
    }

    #[test]
    fn max_min_three_flows_two_resources() {
        // classic: f1 uses r1, f2 uses r2, f3 uses both. r1=r2=100.
        // max-min: f3 gets 50, f1 and f2 get 50 each... progressive
        // filling: fair share r1 = 100/2 = 50, r2 = 50 -> all at 50.
        let mut sim = Sim::new();
        let r1 = sim.add_resource("r1", 100.0);
        let r2 = sim.add_resource("r2", 100.0);
        let f1 = one_flow(&mut sim, vec![r1], 500.0, f64::INFINITY);
        let f2 = one_flow(&mut sim, vec![r2], 500.0, f64::INFINITY);
        let f3 = one_flow(&mut sim, vec![r1, r2], 500.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        // all rate 50 until f3 done at t=10; f1,f2 also done at t=10.
        for (n, f) in [("f1", &f1), ("f2", &f2), ("f3", &f3)] {
            assert!((f.get() - 10.0).abs() < 1e-6, "{n} at {}", f.get());
        }
    }

    #[test]
    fn unequal_paths_max_min() {
        // r1 = 100 shared by fA (r1 only) and fB (r1+r2), r2 = 30.
        // fB bottlenecked by r2 at 30; fA then gets 70.
        let mut sim = Sim::new();
        let r1 = sim.add_resource("r1", 100.0);
        let r2 = sim.add_resource("r2", 30.0);
        let a = one_flow(&mut sim, vec![r1], 700.0, f64::INFINITY);
        let b = one_flow(&mut sim, vec![r1, r2], 300.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert!((a.get() - 10.0).abs() < 1e-6, "a at {}", a.get());
        assert!((b.get() - 10.0).abs() < 1e-6, "b at {}", b.get());
    }

    #[test]
    fn zero_unit_flow_completes_instantly() {
        let mut sim = Sim::new();
        let r = sim.add_resource("r", 1.0);
        let t = one_flow(&mut sim, vec![r], 0.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert_eq!(t.get(), 0.0);
    }

    #[test]
    fn timers_fire_in_order() {
        struct Sleeper {
            phase: u32,
            log: std::rc::Rc<std::cell::RefCell<Vec<f64>>>,
        }
        impl Process for Sleeper {
            fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
                self.log.borrow_mut().push(sim.now());
                self.phase += 1;
                if self.phase <= 3 {
                    sim.sleep(pid, 1.5);
                    Step::Waiting
                } else {
                    Step::Done
                }
            }
        }
        let mut sim = Sim::new();
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        sim.spawn(Box::new(Sleeper { phase: 0, log: log.clone() }));
        sim.run(1e9).unwrap();
        assert_eq!(&*log.borrow(), &[0.0, 1.5, 3.0, 4.5]);
    }

    #[test]
    fn max_time_guard_trips() {
        let mut sim = Sim::new();
        let r = sim.add_resource("slow", 1.0);
        let _t = one_flow(&mut sim, vec![r], 1e12, f64::INFINITY);
        assert!(sim.run(10.0).is_err());
    }

    #[test]
    fn resource_work_accounted() {
        let mut sim = Sim::new();
        let r = sim.add_resource("disk", 100.0);
        let _ = one_flow(&mut sim, vec![r], 1000.0, f64::INFINITY);
        sim.run(1e9).unwrap();
        assert!((sim.resource_work(r) - 1000.0).abs() < 1e-6);
    }
}
