//! Fluid-flow discrete-event simulator of the paper's HPC testbed.
//!
//! The paper evaluates Sea on a physical cluster (8 compute nodes, 4-OSS
//! Lustre, 25 GbE). None of that hardware exists here, so this module
//! builds the *closest synthetic equivalent that exercises the same code
//! path* (DESIGN.md §2): a fluid-flow DES in the SimGrid tradition.
//!
//! * [`engine`] — event queue, **max-min fair** bandwidth sharing
//!   (progressive filling with per-flow rate caps), cooperative processes.
//! * [`spec`] — cluster description; defaults replicate the paper's
//!   testbed calibrated with Table 2 bandwidths.
//! * [`topology`] — maps the spec onto engine resources (per-node memory
//!   bus, CPU, NIC, disks; per-OSS NIC; per-OST disk; MDS service).
//! * [`pagecache`] — per-node Linux page-cache model: LRU clean pages,
//!   dirty accounting, `dirty_ratio` throttling, async writeback.
//! * [`stack`] — the storage stack: read/write/delete/copy operations
//!   against tmpfs / local disks / Lustre, routed through the page cache,
//!   with MDS metadata costs for Lustre ops.
//! * [`app`] — the instruction-VM used to run workload programs
//!   (sequential blocking I/O + compute per simulated process).
//!
//! The same placement logic (`hierarchy`/`placement`) drives both this
//! simulator and the real-bytes VFS, so a policy bug shows up in both.

pub mod app;
pub mod engine;
pub mod pagecache;
pub mod spec;
pub mod stack;
pub mod topology;

pub use app::{AppProc, FlushDaemon, Instr, MgmtAction, MgmtQueues, RunOutcome, SimPlacer};
pub use engine::{FlowId, ProcId, Process, ResourceId, Sim, Step};
pub use spec::{ClusterSpec, LustreSpec};
pub use stack::{FileId, Stack, StackStats};
pub use topology::{Location, Topology};
