//! # sea — reproduction of *"Sea: A lightweight data-placement library for
//! Big Data scientific computing"* (Hayot-Sasson, Dugré, Glatard, 2022)
//!
//! Sea intercepts POSIX file-system calls made by unmodified scientific
//! pipelines and transparently redirects files under a user mountpoint to
//! the fastest storage device with sufficient space in a user-declared
//! hierarchy (tmpfs → local disks → parallel file system), with rule-driven
//! flush / evict / prefetch memory management.
//!
//! This crate is the Layer-3 Rust coordinator of a three-layer stack
//! (see `DESIGN.md`):
//!
//! * [`vfs`] — the interception layer: a `Vfs` trait with real
//!   (`std::fs`) and simulated backends, and `SeaFs` implementing the
//!   paper's mountpoint translation on top of any backend.
//! * [`serve`] — Sea as a service: the `sea serve` daemon owning one
//!   `SeaFs` mount for many client processes, its Unix-socket wire
//!   protocol, and the [`vfs::remote::RemoteFs`] client transport.
//! * [`hierarchy`] + [`placement`] — storage tiers, space accounting,
//!   and the **`PlacementEngine`** decision surface: typed lifecycle
//!   hooks (`place`, `on_access`, `on_close`, `on_pressure`,
//!   `on_freed`) returning typed decisions (flush / evict / spill-self
//!   / spill-victim / promote). Two engines ship — `paper` (the
//!   `.sea_flushlist` / `.sea_evictlist` / `.sea_prefetchlist` Table 1
//!   policy, verbatim) and `temperature` (recency/size heat: coldest
//!   resident spills first, hot spilled files promote back) — selected
//!   via `[sea] engine = "..."` TOML or `sea run --engine`; simulator
//!   and real-bytes VFS drive the same engines.
//! * [`sim`] — a fluid-flow discrete-event cluster simulator (Lustre with
//!   MDS/OSS/OST, per-node page cache with dirty-ratio writeback, local
//!   disks, NICs) standing in for the paper's physical testbed.
//! * [`model`] — the analytic performance model, Eqs. (1)–(11).
//! * [`runtime`] — PJRT loader/executor for the AOT-lowered JAX/Pallas
//!   compute (`artifacts/*.hlo.txt`); Python never runs at request time.
//! * [`workload`] + [`coordinator`] — the incrementation application
//!   (paper Algorithm 1) and the leader/worker pipeline driver.
//! * [`obs`] — observability: lock-free latency histograms (p50/p95/p99
//!   per op class × layer, surfaced by `sea stat` locally and over the
//!   wire) and a flight recorder dumping Chrome trace-event JSON
//!   (`sea run --trace` / `SEA_TRACE`).
//! * [`bench`], [`testkit`] — offline substitutes for criterion/proptest.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod hierarchy;
pub mod model;
pub mod obs;
pub mod placement;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod vfs;
pub mod workload;
pub mod sim;
pub mod util;

pub use error::{Error, Result};
