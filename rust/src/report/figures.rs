//! Paper-figure sweep drivers: each function regenerates one figure's
//! data by running the simulator at every sweep point for both systems
//! and evaluating the analytic model bounds (shaded regions in Fig 2).
//!
//! These are used by `sea experiment`, by `examples/bigbrain_paper.rs`,
//! and by the `bench_fig2*` / `bench_fig3` bench targets.

use crate::coordinator::{run_experiment, ExperimentCfg, Mode, SimReport};
use crate::error::Result;
use crate::model::{lustre_bounds, sea_bounds, ModelParams};
use crate::report::{FigPoint, Figure};
use crate::sim::spec::ClusterSpec;
use crate::workload::IncrementationSpec;

/// Scale factor applied to the paper workload so sweeps finish quickly
/// on a laptop-class host while preserving all contention ratios.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Multiplier on block count (1.0 = the paper's 1000 blocks).
    pub blocks: f64,
}

impl Scale {
    /// Full paper scale.
    pub fn paper() -> Scale {
        Scale { blocks: 1.0 }
    }

    /// Quick scale for CI / benches (1/10 of the blocks).
    pub fn quick() -> Scale {
        Scale { blocks: 0.1 }
    }

    fn apply(&self, w: &IncrementationSpec) -> IncrementationSpec {
        let mut w = w.clone();
        w.blocks = ((w.blocks as f64 * self.blocks).round() as usize).max(1);
        w
    }
}

fn point(
    spec: &ClusterSpec,
    workload: &IncrementationSpec,
    x: f64,
    seed: u64,
) -> Result<(FigPoint, SimReport, SimReport)> {
    let lustre = run_experiment(&ExperimentCfg {
        spec: spec.clone(),
        workload: workload.clone(),
        mode: Mode::Lustre,
        seed,
    })?;
    let sea = run_experiment(&ExperimentCfg {
        spec: spec.clone(),
        workload: workload.clone(),
        mode: Mode::SeaInMemory,
        seed,
    })?;
    let params = ModelParams::from_spec(spec, workload.file_size);
    let vol = workload.volume();
    let p = FigPoint {
        x,
        lustre: lustre.makespan,
        sea: sea.makespan,
        lustre_bounds: lustre_bounds(&params, &vol),
        sea_bounds: sea_bounds(&params, &vol),
    };
    Ok((p, lustre, sea))
}

/// Fig 2a: vary the number of nodes (paper: 10 iterations).
pub fn fig2a(base: &ClusterSpec, scale: Scale, nodes: &[usize], seed: u64) -> Result<Figure> {
    let mut w = IncrementationSpec::paper_default();
    w.iterations = 10;
    let w = scale.apply(&w);
    let mut points = Vec::new();
    for &n in nodes {
        let mut spec = base.clone();
        spec.nodes = n;
        points.push(point(&spec, &w, n as f64, seed)?.0);
    }
    Ok(Figure {
        id: "fig2a".into(),
        title: "Fig 2a: varying nodes (10 iterations)".into(),
        xlabel: "nodes".into(),
        points,
    })
}

/// Fig 2b: vary the number of local disks (paper: 5 iterations).
pub fn fig2b(base: &ClusterSpec, scale: Scale, disks: &[usize], seed: u64) -> Result<Figure> {
    let mut w = IncrementationSpec::paper_default();
    w.iterations = 5;
    let w = scale.apply(&w);
    let mut points = Vec::new();
    for &d in disks {
        let mut spec = base.clone();
        spec.disks_per_node = d;
        points.push(point(&spec, &w, d as f64, seed)?.0);
    }
    Ok(Figure {
        id: "fig2b".into(),
        title: "Fig 2b: varying local disks (5 iterations)".into(),
        xlabel: "disks per node".into(),
        points,
    })
}

/// Fig 2c: vary the iteration count (intermediate-data volume).
pub fn fig2c(base: &ClusterSpec, scale: Scale, iters: &[usize], seed: u64) -> Result<Figure> {
    let mut points = Vec::new();
    for &n in iters {
        let mut w = IncrementationSpec::paper_default();
        w.iterations = n;
        let w = scale.apply(&w);
        points.push(point(base, &w, n as f64, seed)?.0);
    }
    Ok(Figure {
        id: "fig2c".into(),
        title: "Fig 2c: varying iterations".into(),
        xlabel: "iterations".into(),
        points,
    })
}

/// Fig 2d: vary parallel processes per node (paper: 5 iterations).
pub fn fig2d(base: &ClusterSpec, scale: Scale, procs: &[usize], seed: u64) -> Result<Figure> {
    let mut w = IncrementationSpec::paper_default();
    w.iterations = 5;
    let w = scale.apply(&w);
    let mut points = Vec::new();
    for &p in procs {
        let mut spec = base.clone();
        spec.procs_per_node = p;
        points.push(point(&spec, &w, p as f64, seed)?.0);
    }
    Ok(Figure {
        id: "fig2d".into(),
        title: "Fig 2d: varying parallel processes (5 iterations)".into(),
        xlabel: "processes per node".into(),
        points,
    })
}

/// Fig 3 rows: the three modes at fixed conditions (5 nodes, 6 procs,
/// 6 disks, 5 iterations).
pub fn fig3(base: &ClusterSpec, scale: Scale, seed: u64) -> Result<Vec<(String, SimReport)>> {
    let mut w = IncrementationSpec::paper_default();
    w.iterations = 5;
    let w = scale.apply(&w);
    let mut rows = Vec::new();
    for mode in [Mode::Lustre, Mode::SeaInMemory, Mode::SeaCopyAll] {
        let name = mode.name().to_string();
        let r = run_experiment(&ExperimentCfg {
            spec: base.clone(),
            workload: w.clone(),
            mode,
            seed,
        })?;
        rows.push((name, r));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn tiny_spec() -> ClusterSpec {
        let mut s = ClusterSpec::paper_default();
        s.nodes = 2;
        s.procs_per_node = 2;
        // shrink RAM so the workload exceeds page cache — the paper's
        // stated precondition for Sea speedups (§3.1.1)
        s.mem_bytes = 16 * crate::util::GIB;
        s.tmpfs_bytes = 8 * crate::util::GIB;
        s
    }

    /// Very small scale so tests stay fast.
    fn tiny_scale() -> Scale {
        Scale { blocks: 0.05 } // 50 blocks
    }

    #[test]
    fn fig2c_shows_sea_advantage_growing_with_iterations() {
        let f = fig2c(&tiny_spec(), tiny_scale(), &[1, 5, 10], 1).unwrap();
        assert_eq!(f.points.len(), 3);
        let s1 = f.points[0].speedup();
        let s10 = f.points[2].speedup();
        assert!(
            s10 > s1,
            "speedup should grow with iterations: {s1:.2} -> {s10:.2}"
        );
    }

    #[test]
    fn figures_write_csv_and_ascii() {
        let f = fig2c(&tiny_spec(), tiny_scale(), &[1, 5], 1).unwrap();
        let dir = std::env::temp_dir().join("sea_figtest");
        let (csv, txt) = f.write_to(&dir).unwrap();
        assert!(csv.exists() && txt.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig3_orders_modes_as_paper() {
        // in-memory fastest; flush-all slowest (slower than lustre too)
        let mut spec = tiny_spec();
        spec.procs_per_node = 4;
        let rows = fig3(&spec, tiny_scale(), 3).unwrap();
        let get = |m: &str| rows.iter().find(|(n, _)| n == m).unwrap().1.makespan;
        let im = get("sea-in-memory");
        let lu = get("lustre");
        let fa = get("sea-flush-all");
        assert!(im < lu, "in-memory {im:.1} < lustre {lu:.1}");
        assert!(fa > im, "flush-all {fa:.1} > in-memory {im:.1}");
    }

    #[test]
    fn scale_preserves_file_size() {
        let w = IncrementationSpec::paper_default();
        let s = Scale::quick().apply(&w);
        assert_eq!(s.file_size, 617 * MIB);
        assert_eq!(s.blocks, 100);
    }
}
