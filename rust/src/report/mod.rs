//! Figure/table emission: every paper artifact is written as CSV (exact
//! numbers) plus an ASCII chart (shape at a glance) under `results/`.

pub mod figures;

pub use figures::{fig2a, fig2b, fig2c, fig2d, fig3, Scale};

use std::path::{Path, PathBuf};

use crate::coordinator::SimReport;
use crate::error::Result;
use crate::model::Bounds;
use crate::util::ascii_plot::Plot;
use crate::util::csv::{f, Csv};

/// One sweep point of a figure: x value, measured makespans, model bounds.
#[derive(Debug, Clone)]
pub struct FigPoint {
    /// Sweep coordinate (nodes / disks / iterations / processes).
    pub x: f64,
    /// Lustre measured makespan (s).
    pub lustre: f64,
    /// Sea measured makespan (s).
    pub sea: f64,
    /// Lustre model bounds.
    pub lustre_bounds: Bounds,
    /// Sea model bounds.
    pub sea_bounds: Bounds,
}

impl FigPoint {
    /// Speedup of Sea over Lustre at this point.
    pub fn speedup(&self) -> f64 {
        if self.sea > 0.0 { self.lustre / self.sea } else { f64::NAN }
    }
}

/// A complete figure: sweep label + points.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Figure id (e.g. `fig2a`).
    pub id: String,
    /// Human title.
    pub title: String,
    /// X-axis label.
    pub xlabel: String,
    /// The sweep.
    pub points: Vec<FigPoint>,
}

impl Figure {
    /// Serialize to CSV rows matching the paper's series.
    pub fn to_csv(&self) -> Csv {
        let mut c = Csv::new(vec![
            "x",
            "lustre_s",
            "sea_s",
            "speedup",
            "lustre_model_lo",
            "lustre_model_hi",
            "sea_model_lo",
            "sea_model_hi",
        ]);
        for p in &self.points {
            c.row(vec![
                f(p.x),
                f(p.lustre),
                f(p.sea),
                f(p.speedup()),
                f(p.lustre_bounds.lower),
                f(p.lustre_bounds.upper),
                f(p.sea_bounds.lower),
                f(p.sea_bounds.upper),
            ]);
        }
        c
    }

    /// Render the ASCII chart with measured lines + model-bound bands.
    pub fn to_ascii(&self) -> String {
        let lustre: Vec<(f64, f64)> = self.points.iter().map(|p| (p.x, p.lustre)).collect();
        let sea: Vec<(f64, f64)> = self.points.iter().map(|p| (p.x, p.sea)).collect();
        let lb: Vec<(f64, f64, f64)> = self
            .points
            .iter()
            .map(|p| (p.x, p.lustre_bounds.lower, p.lustre_bounds.upper))
            .collect();
        let sb: Vec<(f64, f64, f64)> = self
            .points
            .iter()
            .map(|p| (p.x, p.sea_bounds.lower, p.sea_bounds.upper))
            .collect();
        Plot::new(&self.title, &self.xlabel, "makespan (s)")
            .band("lustre model bounds", '.', lb)
            .band("sea model bounds", ':', sb)
            .series("lustre (measured)", 'L', lustre)
            .series("sea (measured)", 'S', sea)
            .render()
    }

    /// Write `<dir>/<id>.csv` and `<dir>/<id>.txt`.
    pub fn write_to(&self, dir: &Path) -> Result<(PathBuf, PathBuf)> {
        let csv_path = dir.join(format!("{}.csv", self.id));
        let txt_path = dir.join(format!("{}.txt", self.id));
        self.to_csv().write_to(&csv_path)?;
        std::fs::create_dir_all(dir)
            .map_err(|e| crate::error::Error::io(dir, e))?;
        std::fs::write(&txt_path, self.to_ascii())
            .map_err(|e| crate::error::Error::io(&txt_path, e))?;
        Ok((csv_path, txt_path))
    }

    /// Max speedup across points (headline number).
    pub fn max_speedup(&self) -> f64 {
        self.points.iter().map(|p| p.speedup()).fold(f64::NAN, f64::max)
    }
}

/// Summarize a [`SimReport`] as console lines (used by `sea sim`).
pub fn describe_run(r: &SimReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "mode           : {}", r.mode);
    let _ = writeln!(s, "makespan       : {:.2} s", r.makespan);
    let _ = writeln!(s, "app done       : {:.2} s", r.app_done);
    let _ = writeln!(s, "quiescent      : {:.2} s", r.quiescent);
    let _ = writeln!(s, "flushes/evicts : {}/{}", r.flushes, r.evictions);
    let _ = writeln!(s, "mds ops        : {:.0}", r.stats.mds_ops);
    let hit_ratio = if r.cache_hits + r.cache_misses > 0 {
        r.cache_hits as f64 / (r.cache_hits + r.cache_misses) as f64
    } else {
        0.0
    };
    let _ = writeln!(s, "cache hit ratio: {:.1}%", hit_ratio * 100.0);
    let mut tiers: Vec<_> = r.stats.tiers.iter().collect();
    tiers.sort_by_key(|(k, _)| *k);
    for (tier, b) in tiers {
        let _ = writeln!(
            s,
            "  {tier:<11}: read {:>10} written {:>10} (cache r/w {:>10}/{:>10})",
            crate::util::fmt_bytes(b.read),
            crate::util::fmt_bytes(b.written),
            crate::util::fmt_bytes(b.cache_read),
            crate::util::fmt_bytes(b.cache_write),
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Figure {
        Figure {
            id: "figx".into(),
            title: "test".into(),
            xlabel: "n".into(),
            points: vec![
                FigPoint {
                    x: 1.0,
                    lustre: 100.0,
                    sea: 50.0,
                    lustre_bounds: Bounds { lower: 40.0, upper: 120.0 },
                    sea_bounds: Bounds { lower: 30.0, upper: 60.0 },
                },
                FigPoint {
                    x: 2.0,
                    lustre: 90.0,
                    sea: 30.0,
                    lustre_bounds: Bounds { lower: 35.0, upper: 110.0 },
                    sea_bounds: Bounds { lower: 20.0, upper: 45.0 },
                },
            ],
        }
    }

    #[test]
    fn csv_has_all_series() {
        let c = fig().to_csv();
        let s = c.to_string();
        assert!(s.starts_with("x,lustre_s,sea_s,speedup"));
        assert_eq!(c.len(), 2);
        assert!(s.contains("100.000000"));
    }

    #[test]
    fn speedup_and_headline() {
        let f = fig();
        assert!((f.points[0].speedup() - 2.0).abs() < 1e-9);
        assert!((f.max_speedup() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ascii_renders_both_series() {
        let a = fig().to_ascii();
        assert!(a.contains('L') && a.contains('S'));
        assert!(a.contains("sea model bounds"));
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("sea_report_test");
        let (csv, txt) = fig().write_to(&dir).unwrap();
        assert!(csv.exists() && txt.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
